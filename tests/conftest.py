"""Shared pytest configuration.

NOTE: no XLA_FLAGS / device-count manipulation here — smoke tests and
benches must see the real single CPU device; only launch/dryrun.py (and the
subprocess in test_distributed.py) request placeholder device counts.
"""
import sys
from pathlib import Path

# Make `repro` importable from a plain checkout (no PYTHONPATH=src and no
# `pip install -e .` needed) — a site-installed copy still wins if present.
_SRC = str(Path(__file__).resolve().parent.parent / "src")
if _SRC not in sys.path:
    sys.path.append(_SRC)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (subprocess compile/execute)")
    config.addinivalue_line(
        "markers", "chaos: fault-injection robustness test (engine "
        "preemption/cancel/deadline invariants under a FaultPlan)")
