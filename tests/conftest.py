"""Shared pytest configuration.

NOTE: no XLA_FLAGS / device-count manipulation here — smoke tests and
benches must see the real single CPU device; only launch/dryrun.py (and the
subprocess in test_distributed.py) request placeholder device counts.
"""


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (subprocess compile/execute)")
