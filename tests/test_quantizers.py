"""Quantizer unit + property tests (INT4/INT8/FP4/MXFP4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import quantizers as qz

FP4_GRID = {0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0}


def test_fp4_values_on_grid():
    v = jnp.linspace(-10, 10, 4001)
    q = np.asarray(qz.fp4_quantize(v, jnp.array(1.0)))
    assert set(np.round(np.abs(q), 6).tolist()) <= FP4_GRID


def test_fp4_exact_grid_points_are_fixed():
    pts = jnp.asarray(sorted(FP4_GRID | {-g for g in FP4_GRID}))
    q = qz.fp4_quantize(pts, jnp.array(1.0))
    np.testing.assert_allclose(np.asarray(q), np.asarray(pts), atol=1e-7)


@settings(max_examples=50, deadline=None)
@given(st.floats(-1e4, 1e4, allow_nan=False, width=32))
def test_fp4_nearest_neighbor(v):
    """fp4_quantize == LUT nearest neighbor (up to round-half-even ties)."""
    s = 1.0
    q = float(qz.fp4_quantize(jnp.array(v, jnp.float32), jnp.array(s)))
    grid = np.asarray(sorted(FP4_GRID | {-g for g in FP4_GRID}))
    clipped = np.clip(v, -6.0, 6.0)
    best = grid[np.argmin(np.abs(grid - clipped))]
    # ties between two grid points are allowed to round either way
    assert abs(q - best) <= max(abs(grid - clipped).min() * 1.0001, 1e-6) or \
        np.isclose(abs(q - clipped), abs(best - clipped), rtol=1e-5)


@pytest.mark.parametrize("bits", [4, 8])
def test_int_quantize_error_bound(bits):
    """Worst-case per-element error ≤ s/2 inside the clip range."""
    x = jax.random.normal(jax.random.PRNGKey(0), (64,)) * 2
    s = jnp.max(jnp.abs(x)) / (2 ** (bits - 1) - 1)
    q = qz.int_quantize(x, s, 0.0, bits)
    assert float(jnp.max(jnp.abs(q - x))) <= float(s) / 2 + 1e-6


@pytest.mark.parametrize("fmt", ["int4", "int8", "fp4", "mxfp4"])
def test_act_quant_shape_dtype(fmt):
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 5, 64), jnp.bfloat16)
    y = qz.quantize_act(x, qz.QuantSpec(fmt=fmt))
    assert y.shape == x.shape and y.dtype == x.dtype


@pytest.mark.parametrize("fmt", ["int4", "fp4", "mxfp4"])
def test_weight_quant_reduces_to_grid(fmt):
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
    wq = qz.quantize_weight(w, qz.QuantSpec(fmt=fmt), axis=0)
    assert wq.shape == w.shape
    # idempotence: quantizing a quantized weight is (nearly) a fixed point
    wq2 = qz.quantize_weight(wq, qz.QuantSpec(fmt=fmt), axis=0)
    assert float(jnp.linalg.norm(wq2 - wq)) <= 0.35 * float(jnp.linalg.norm(wq - w))


def test_mse_scale_search_beats_absmax_scale():
    """The Appendix-B linear search should not be worse than plain absmax."""
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (128, 16)) * jnp.exp(
        jax.random.normal(jax.random.PRNGKey(4), (128, 16)))
    bits = 4
    s_search = qz.int_weight_scales_mse(w, bits, axis=0)
    s_absmax = jnp.max(jnp.abs(w), axis=0, keepdims=True) / (2 ** (bits - 1) - 1)
    e_search = jnp.sum((qz.int_quantize(w, s_search, 0., bits) - w) ** 2)
    e_absmax = jnp.sum((qz.int_quantize(w, s_absmax, 0., bits) - w) ** 2)
    assert float(e_search) <= float(e_absmax) * 1.0001


def test_mxfp4_group_scales_are_pow2():
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 64)) * 100
    q = qz.mxfp4_quantize(x, group=32)
    g = np.asarray(q).reshape(4, 2, 32)
    nz = np.abs(g[np.abs(g) > 0])
    # every quantized magnitude = fp4_value · 2^k → log2(q / fp4val) integral
    vals = np.asarray([0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0])
    ok = np.zeros_like(nz, dtype=bool)
    for v in vals:
        r = nz / v
        ok |= np.isclose(np.log2(r), np.round(np.log2(r)), atol=1e-5)
    assert ok.all()


def test_ste_round_gradient_is_identity():
    g = jax.grad(lambda x: jnp.sum(qz.ste_round(x * 3.0)))(jnp.ones((4,)))
    np.testing.assert_allclose(np.asarray(g), 3.0 * np.ones(4), atol=1e-6)


def test_asym_act_quant_covers_range():
    """Asymmetric per-token quant: min/max of each token map near themselves."""
    x = jnp.asarray(np.random.default_rng(0).uniform(2.0, 9.0, (8, 64)),
                    jnp.float32)  # strictly positive → asym must adapt zero
    y = qz.quantize_act(x, qz.QuantSpec(fmt="int4"))
    sym_scale = jnp.max(jnp.abs(x), -1, keepdims=True) / 7
    y_sym = qz.int_quantize(x, sym_scale, 0.0, 4)
    assert float(jnp.mean((y - x) ** 2)) < float(jnp.mean((y_sym - x) ** 2))
