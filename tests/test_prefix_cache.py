"""Prefix-sharing radix cache: tree semantics, COW, eviction, accounting.

Two layers. The radix-tree unit tests drive `RadixCache` against a
`PagedKVCache` built over an *empty* kv pytree — scrub and COW become
bookkeeping no-ops, so page-aligned insert/match/split/evict semantics
and the refcount ownership contract are exercised at allocator speed.
The engine integration tests then serve real shared-prefix traffic
through `ServeEngine(prefix_cache=True)` and assert the user-visible
promises: cached prefixes skip prefill work, generations stay
bit-identical to the cache-off run, partial-page hits go through exactly
one fused COW copy, the tree honours its page budget, replay accounting
charges only recomputed tokens, and — under injected chaos — a page is
scrubbed only at refcount 0, never under a surviving holder.
"""
import jax
import pytest

from repro.configs.registry import get_config
from repro.models.transformer import build_model
from repro.serve.engine import (EngineRequest, FaultPlan, PagedKVCache,
                                RadixCache, SamplingParams, ServeEngine,
                                as_servable)

PS = 4          # page size for the unit tests
N_PAGES = 16


def _kvc():
    """KV bookkeeping with no device state: scrub/COW are no-ops."""
    return PagedKVCache({}, N_PAGES, PS)


def _tree(max_pages=None):
    kvc = _kvc()
    return RadixCache(kvc, max_pages), kvc.allocator, kvc


# ----------------------------------------------------------------------
# radix tree unit tests
# ----------------------------------------------------------------------


def test_empty_tree_matches_nothing():
    tree, _, _ = _tree()
    assert tree.match([1, 2, 3]) == ([], None)
    assert tree.n_pages == 0 and tree.n_nodes == 0
    assert tree.held_pages() == set()


def test_insert_match_roundtrip():
    tree, alloc, _ = _tree()
    toks = list(range(100, 112))            # 3 pages of 4
    pages = alloc.alloc(3)
    assert tree.insert(toks, pages) == 3
    assert tree.n_pages == 3 and tree.inserted_pages == 3
    assert tree.held_pages() == set(pages)
    assert alloc.in_use == 3                # ownership moved, not copied
    # longer stream: full-run match, divergence past the cached edge
    assert tree.match(toks + [7, 8]) == (pages, None)
    # exact stream: full pages, no COW candidate
    assert tree.match(list(toks)) == (pages, None)
    # diverges 2 tokens into page 1: full page 0 + a COW peek at page 1
    got, cow = tree.match(toks[:6] + [999] * 6)
    assert got == pages[:1] and cow == (pages[1], 2)
    # diverges inside page 0: nothing page-aligned to share
    assert tree.match([999] + toks) == ([], None)


def test_duplicate_insert_consumes_and_frees_the_copy():
    tree, alloc, _ = _tree()
    toks = list(range(12))
    first = alloc.alloc(3)
    tree.insert(toks, first)
    dup = alloc.alloc(3)
    assert tree.insert(toks, dup) == 0      # already cached: adopt nothing
    assert alloc.in_use == 3                # dup refs consumed → freed
    assert tree.n_pages == 3
    assert tree.match(list(toks)) == (first, None)


def test_page_boundary_split_branches_the_tree():
    tree, alloc, _ = _tree()
    a = list(range(12))
    b = a[:8] + [50, 51, 52, 53]            # shares exactly 2 pages
    pa, pb = alloc.alloc(3), alloc.alloc(3)
    tree.insert(a, pa)
    assert tree.insert(b, pb) == 1          # only the divergent page
    assert alloc.in_use == 4                # b's two duplicate pages freed
    assert tree.n_pages == 4 and tree.n_nodes == 3
    assert tree.match(list(a)) == (pa, None)
    assert tree.match(list(b)) == (pa[:2] + [pb[2]], None)


def test_mid_page_divergence_keeps_only_the_aligned_prefix():
    tree, alloc, _ = _tree()
    a = list(range(12))
    pa = alloc.alloc(3)
    tree.insert(a, pa)
    # shares 6 tokens = 1 page + half of the second: the remainder can't
    # become a page-aligned sibling, so everything past page 0 is dropped
    b = a[:6] + [70] * 6
    pb = alloc.alloc(3)
    assert tree.insert(b, pb) == 0
    assert alloc.in_use == 3 and tree.n_pages == 3
    got, cow = tree.match(list(b))
    assert got == pa[:1] and cow == (pa[1], 2)


def test_misaligned_insert_raises():
    tree, alloc, _ = _tree()
    pages = alloc.alloc(2)
    with pytest.raises(ValueError, match="page-aligned"):
        tree.insert(list(range(7)), pages)
    alloc.free(pages)


def test_lru_eviction_respects_budget():
    tree, alloc, _ = _tree(max_pages=4)
    a, b = list(range(12)), list(range(20, 32))
    tree.insert(a, alloc.alloc(3))
    pb = alloc.alloc(3)
    tree.insert(b, pb)                      # over budget → evict LRU (a)
    assert tree.n_pages <= 4
    assert tree.evicted_pages == 2
    assert tree.match(list(b)) == (pb, None)     # newest insert intact
    assert len(tree.match(list(a))[0]) <= 1      # a's tail evicted
    assert alloc.in_use == tree.n_pages          # evicted pages freed


def test_evict_skips_pages_pinned_by_live_holders():
    tree, alloc, kvc = _tree()
    toks = list(range(12))
    pages = alloc.alloc(3)
    tree.insert(toks, pages)
    alloc.incref([pages[1]])                # a live sequence shares page 1
    assert tree.evict(3) == 1               # only the free tail goes
    assert tree.n_pages == 2
    assert tree.held_pages() == set(pages[:2])
    assert alloc.refcount(pages[1]) == 2
    assert tree.evict(3) == 0               # pinned page blocks the rest
    kvc.deref([pages[1]])                   # holder lets go
    assert tree.evict(3) == 2
    assert tree.n_pages == 0 and alloc.in_use == 0


def test_clear_releases_every_page():
    tree, alloc, _ = _tree()
    tree.insert(list(range(12)), alloc.alloc(3))
    tree.insert(list(range(12))[:8] + [9, 9, 9, 9], alloc.alloc(3))
    held = tree.n_pages
    assert tree.clear() == held
    assert tree.n_pages == 0 and tree.n_nodes == 0
    assert alloc.in_use == 0
    assert tree.match(list(range(12))) == ([], None)


# ----------------------------------------------------------------------
# engine integration
# ----------------------------------------------------------------------

MAX_NEW = 4
SYS = [11, 23, 5, 81, 42, 17, 3, 64, 29, 90, 7, 55]     # 3 pages of 4
SUFFIXES = [[101, 7, 33], [88, 12, 60, 4], [19, 2], [73, 41, 6, 5, 28]]
GEOM = dict(n_pages=40, page_size=4, max_seqs=2, prefill_chunk=4)


@pytest.fixture(scope="module")
def adapter():
    cfg = get_config("llama3-1b").reduced()
    model = build_model(cfg)
    return as_servable(model, model.init(jax.random.PRNGKey(0)))


def _submit(eng, prompts):
    for rid, p in enumerate(prompts):
        eng.submit(EngineRequest(rid=rid, prompt=list(p),
                                 sampling=SamplingParams(max_new=MAX_NEW)))


def _run_checked(eng):
    done = []
    while eng.queue or eng.active:
        done.extend(eng.step())
        eng.check_books()
    return {r.rid: r for r in done}


def _counter(eng, name):
    return eng.metrics.counter(name).value


def _assert_drained_but_tree(eng):
    """Quiescent engine: the only live references are the tree's."""
    alloc = eng.kv.allocator
    tree = eng.prefix_cache
    assert not eng.kv.tables and not eng._committed
    assert alloc.in_use == (tree.n_pages if tree else 0)
    eng.check_books()
    if tree:
        tree.clear()
    assert alloc.in_use == 0 and alloc.n_free == alloc.capacity


@pytest.fixture(scope="module")
def baseline(adapter):
    """Cache-off greedy tokens + prefill cost for the shared workload."""
    eng = ServeEngine(adapter, **GEOM)
    _submit(eng, [SYS + s for s in SUFFIXES])
    done = _run_checked(eng)
    return ({r: done[r].generated for r in done},
            _counter(eng, "engine.prefill_tokens"))


def test_prefix_hits_skip_prefill_bit_identically(adapter, baseline):
    """The headline promise: later requests sharing the system prefix
    prefill only their divergent tail, generate the exact cache-off
    tokens, and the saving shows up in the counters."""
    base_toks, base_prefill = baseline
    eng = ServeEngine(adapter, **GEOM, prefix_cache=True)
    _submit(eng, [SYS + s for s in SUFFIXES])
    done = _run_checked(eng)
    for rid, toks in base_toks.items():
        assert done[rid].generated == toks, rid
    assert _counter(eng, "engine.prefix.hits") > 0
    assert _counter(eng, "engine.prefix.hit_tokens") > 0
    assert _counter(eng, "engine.prefill_tokens") < base_prefill
    assert eng.prefix_cache.n_pages > 0
    _assert_drained_but_tree(eng)


def test_partial_page_hit_goes_through_one_cow_copy(adapter):
    """A prompt that equals a cached stream's page-aligned prefix clamps
    to len-1 (the last position must produce logits), landing mid-page:
    exactly one fused COW copy, and the continuation matches a cold run."""
    eng = ServeEngine(adapter, **GEOM, prefix_cache=True)
    donor = SYS + SUFFIXES[0]
    _submit(eng, [donor])
    _run_checked(eng)
    assert eng.prefix_cache.n_pages >= 2    # donated at finish
    probe = list(SYS[:8])                   # 2 cached pages exactly
    cold = ServeEngine(adapter, **GEOM)
    cold.submit(EngineRequest(rid=0, prompt=list(probe),
                              sampling=SamplingParams(max_new=MAX_NEW)))
    want = _run_checked(cold)[0].generated
    eng.submit(EngineRequest(rid=9, prompt=list(probe),
                             sampling=SamplingParams(max_new=MAX_NEW)))
    done = _run_checked(eng)
    assert done[9].generated == want
    assert _counter(eng, "engine.prefix.cow_copies") == 1
    # clamp: 8 cached tokens available, 7 usable (last recomputed)
    assert _counter(eng, "engine.prefix.hit_tokens") == 7
    _assert_drained_but_tree(eng)


def test_tree_honours_its_page_budget(adapter):
    eng = ServeEngine(adapter, **GEOM, prefix_cache=True,
                      prefix_cache_pages=2)
    _submit(eng, [SYS + s for s in SUFFIXES])
    _run_checked(eng)
    assert eng.prefix_cache.n_pages <= 2
    assert _counter(eng, "engine.prefix.evicted_pages") > 0
    _assert_drained_but_tree(eng)


@pytest.mark.chaos
def test_replay_charges_only_recomputed_tokens(adapter):
    """Satellite accounting fix: `engine.replayed_prefill_tokens` counts
    the rows a replay *actually* recomputes. Fault-free runs charge
    zero. A victim preempted mid-decode replays its whole stream with
    the cache off, but with a warm tree (seeded by an identical earlier
    request — greedy decoding makes its stream a prefix of the donated
    one) the replay recomputes only what the tree cannot return. The
    charge-at-preempt-time accounting this replaced billed the full
    stream in both cases."""
    prompt = SYS + SUFFIXES[0]
    replayed, toks = {}, {}
    for cache_on in (False, True):
        eng = ServeEngine(adapter, **GEOM, prefix_cache=cache_on)
        # warm request: with the cache on, donates its stream's pages
        eng.submit(EngineRequest(rid=0, prompt=list(prompt),
                                 sampling=SamplingParams(max_new=MAX_NEW)))
        warm = _run_checked(eng)[0].generated
        assert _counter(eng, "engine.replayed_prefill_tokens") == 0
        donated = (eng.prefix_cache.n_pages if cache_on else 0) \
            * GEOM["page_size"]
        b = EngineRequest(rid=1, prompt=list(prompt),
                          sampling=SamplingParams(max_new=MAX_NEW))
        eng.submit(b)
        while len(b.generated) < 2:         # decode to a known point
            eng.step()
            eng.check_books()
        eng._preempt(b)
        eng.check_books()
        done = _run_checked(eng)
        assert done[1].generated == warm    # replay continued exactly
        toks[cache_on] = warm
        stream = len(prompt) + 2            # prompt + generated at preempt
        # the replay prefills from the tree hit (clamped: the last
        # position always recomputes) to the end of the stream
        expect = stream - min(donated, stream - 1)
        assert _counter(eng, "engine.preemptions") == 1
        assert _counter(eng, "engine.replayed_prefill_tokens") == expect, \
            (cache_on, donated)
        replayed[cache_on] = expect
        _assert_drained_but_tree(eng)
    assert toks[True] == toks[False]
    assert replayed[False] == len(prompt) + 2   # whole stream recomputed
    assert 0 < replayed[True] < replayed[False]


@pytest.mark.chaos
def test_chaos_sharing_never_scrubs_a_referenced_page(adapter, baseline):
    """Preemption + eviction under sharing: every page handed to the
    fused scrub has refcount 0 at that moment (scrubbing a still-shared
    page would corrupt every surviving holder), and the chaos run's
    tokens stay bit-identical to the undisturbed baseline."""
    base_toks, _ = baseline
    scrubbed = []
    for seed in (1, 2, 3):
        eng = ServeEngine(adapter, n_pages=14, page_size=4, max_seqs=2,
                          prefill_chunk=4, prefix_cache=True,
                          max_preemptions=10,
                          faults=FaultPlan(seed=seed, exhaust_rate=0.3))
        orig = eng.kv.scrub

        def guard(pages, slot, _orig=orig, _eng=eng):
            for p in pages:
                assert _eng.kv.allocator.refcount(p) == 0, \
                    f"scrub of live page {p}"
            scrubbed.extend(pages)
            return _orig(pages, slot)

        eng.kv.scrub = guard
        _submit(eng, [SYS + s for s in SUFFIXES])
        done = _run_checked(eng)
        for rid, toks in base_toks.items():
            assert done[rid].generated == toks, (seed, rid)
        _assert_drained_but_tree(eng)
    assert scrubbed        # the guard actually saw traffic
