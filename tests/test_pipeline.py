"""End-to-end PTQ pipeline tests.

The two central invariants:
  1. *Function preservation*: with quantization disabled, the full transform
     stack (norm folding + R₁/R₂ merging + P₃ permutation + R̃₃ pre-rotation
     and its online inverse) leaves the model function unchanged.
  2. *The paper's claim*: with INT4 W4A4, PeRQ (MassDiff) yields lower
     output error than No-Permute at small block sizes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import pipeline as PL
from repro.core.quantizers import QuantSpec
from repro.models.transformer import build_model

KEY = jax.random.PRNGKey(0)
NOQ = QuantSpec(fmt="none")


def _setup(arch, seed=0, **reduced_kw):
    cfg = get_config(arch).reduced(**reduced_kw)
    if cfg.uses_moe:
        cfg = cfg.reduced(capacity_factor=cfg.n_experts / cfg.top_k,
                          **reduced_kw)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _batch(cfg, key, batch=2, seq=32):
    ks = jax.random.split(key, 3)
    if cfg.frontend == "audio_frames":
        return {"frames": jax.random.normal(ks[0], (batch, seq, 512)),
                "labels": jnp.zeros((batch, seq), jnp.int32)}
    if cfg.frontend == "vision_patches":
        npatch = cfg.frontend_tokens
        return {"patches": jax.random.normal(ks[0], (batch, npatch, 1024)),
                "tokens": jax.random.randint(ks[1], (batch, seq - npatch),
                                             0, cfg.vocab),
                "labels": jnp.zeros((batch, seq - npatch), jnp.int32)}
    return {"tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab),
            "labels": jnp.zeros((batch, seq), jnp.int32)}


TRANSFORM_ARCHS = ["llama3-1b", "qwen1.5-0.5b", "hubert-xlarge",
                   "internvl2-2b", "deepseek-moe-16b", "mamba2-1.3b",
                   "zamba2-1.2b"]


@pytest.mark.parametrize("arch", TRANSFORM_ARCHS)
def test_transforms_preserve_function(arch):
    """No-quant pipeline (rotations+permutations merged, rounding off) must
    reproduce the original logits to float tolerance."""
    cfg, model, params = _setup(arch)
    batch = _batch(cfg, KEY)
    cal = [_batch(cfg, jax.random.PRNGKey(7))]
    ptq_cfg = PL.PTQConfig(weight_spec=NOQ, act_spec=NOQ, block_size=16,
                           permutation="massdiff", rotation="quarot",
                           rounding="rtn")
    res = PL.quantize_model(model, params, cal, ptq_cfg)
    # disable runtime act-quant hooks but KEEP the online R̃₃ (its inverse
    # is merged in w_down, so function preservation depends on it running)
    hooks = dict(res.hooks)
    hooks["act_in"] = None
    hooks = {k: v for k, v in hooks.items() if v is not None}
    qmodel = build_model(cfg, quant_hooks=hooks)

    want = np.asarray(model.forward(params, batch), np.float32)
    got = np.asarray(qmodel.forward(res.params, batch), np.float32)
    # orthogonal transforms accumulate f32 roundoff over layers
    scale = np.abs(want).max() + 1e-6
    np.testing.assert_allclose(got / scale, want / scale, atol=2e-3)


def test_perq_beats_no_permute_int4():
    """Paper Table 1 trend at CPU scale: MassDiff < No-Permute output error
    for small block sizes under INT4 W4A4."""
    cfg, model, params = _setup("llama3-1b")
    batch = _batch(cfg, KEY, batch=2, seq=64)
    cal = [_batch(cfg, jax.random.PRNGKey(7), batch=2, seq=64)]
    want = np.asarray(model.forward(params, batch), np.float32)

    def err_for(permutation):
        ptq = PL.PTQConfig(block_size=16, permutation=permutation,
                           rotation="quarot", rounding="rtn")
        res = PL.quantize_model(model, params, cal, ptq)
        qmodel = PL.build_quantized_model(model, res)
        got = np.asarray(qmodel.forward(res.params, batch), np.float32)
        return float(np.mean((got - want) ** 2))

    e_massdiff = err_for("massdiff")
    e_identity = err_for("identity")
    assert e_massdiff < e_identity, (e_massdiff, e_identity)


def test_pipeline_reduces_prop32_bound():
    """MassDiff must reduce max per-block ℓ₁ mass at every layer (the
    quantity Prop 3.2 says governs post-rotation outliers)."""
    cfg, model, params = _setup("llama3-1b")
    cal = [_batch(cfg, jax.random.PRNGKey(7))]
    ptq = PL.PTQConfig(block_size=16, permutation="massdiff",
                       rotation="quarot", rounding="rtn")
    res = PL.quantize_model(model, params, cal, ptq)
    for entry in res.report["per_layer"]:
        assert entry["max_block_l1_after"] <= \
            entry["max_block_l1_before"] * (1 + 1e-6)


@pytest.mark.parametrize("name", ["perq_star", "perq_dagger", "mr_rtn",
                                  "mr_gptq", "mr_qronos", "brq_spin",
                                  "quarot"])
def test_presets_run(name):
    cfg, model, params = _setup("qwen1.5-0.5b")
    batch = _batch(cfg, KEY)
    cal = [_batch(cfg, jax.random.PRNGKey(7))]
    ptq = PL.preset(name, cayley_steps=4)
    res = PL.quantize_model(model, params, cal, ptq)
    qmodel = PL.build_quantized_model(model, res)
    logits = qmodel.forward(res.params, batch)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("fmt", ["int4", "fp4", "mxfp4"])
def test_formats_run(fmt):
    cfg, model, params = _setup("llama3-1b")
    batch = _batch(cfg, KEY)
    cal = [_batch(cfg, jax.random.PRNGKey(7))]
    ptq = PL.PTQConfig(weight_spec=QuantSpec(fmt=fmt),
                       act_spec=QuantSpec(fmt=fmt), block_size=32,
                       permutation="massdiff", rotation="quarot",
                       rounding="rtn")
    res = PL.quantize_model(model, params, cal, ptq)
    qmodel = PL.build_quantized_model(model, res)
    logits = qmodel.forward(res.params, batch)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_pipeline_ordering_matches_paper():
    """On a model with LLM-like outlier channels and adequate calibration,
    the paper's ordering must hold:
        rtn_only > mr_rtn > PeRQ*   (lower output MSE is better)
    and PeRQ* at b=32 closes most of the gap to full-vector QuaRot."""
    from repro.core.synthetic import inject_outlier_channels
    cfg, model, params = _setup("llama3-1b")
    params = inject_outlier_channels(params)
    batch = _batch(cfg, jax.random.PRNGKey(9), batch=2, seq=64)
    cal = [_batch(cfg, jax.random.PRNGKey(100 + i), batch=4, seq=128)
           for i in range(4)]
    want = np.asarray(model.forward(params, batch), np.float32)

    def err(preset_name, **over):
        res = PL.quantize_model(model, params, cal,
                                PL.preset(preset_name, **over))
        qm = PL.build_quantized_model(model, res)
        got = np.asarray(qm.forward(res.params, batch), np.float32)
        return float(np.mean((got - want) ** 2))

    e_none = err("rtn_only")
    e_mr = err("mr_rtn")
    e_perq = err("perq_star")
    e_full = err("quarot")
    assert e_mr < e_none, (e_mr, e_none)
    assert e_perq < e_mr, (e_perq, e_mr)
    # PeRQ* at b=32 recovers most of the block→full-vector gap
    assert e_perq < e_full * 1.25, (e_perq, e_full)
