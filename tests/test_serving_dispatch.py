"""Kernel-vs-reference parity of the dispatched serving path.

`QuantizedDenseLM` routes every online op through `repro.kernels.ops`;
with kernels enabled that is the Pallas path (interpret mode on CPU), with
`use_kernels(False)` the plain-XLA reference path. Both compute the same
arithmetic — the rotation as a dot against the block-diagonal operand, the
quantizers and integer GEMM bit-identically — so prefill and decode must
match *bit for bit* on a smoke config, int codes and float epilogues alike.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels import ops as kops
from repro.models.transformer import build_model
from repro.serve.quantized import QuantizedDenseLM, pack_dense_params

TOKENS = [3, 14, 15, 92, 6]


@pytest.fixture(scope="module")
def packed_setup():
    cfg = get_config("llama3-1b").reduced()   # 2-layer smoke config
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, pack_dense_params(params, cfg)


def _run(qlm, packed, *, kernels: bool):
    with kops.use_kernels(kernels):
        cache = qlm.init_cache(1, 16)
        pre, cache = qlm.prefill(
            packed, jnp.asarray([TOKENS[:3]], jnp.int32), cache)
        dec = []
        for j, t in enumerate(TOKENS[3:]):
            logits, cache = qlm.decode_step(
                packed, jnp.asarray([[t]], jnp.int32), cache,
                jnp.asarray(3 + j, jnp.int32))
            dec.append(np.asarray(logits))
        return np.asarray(pre), np.stack(dec), cache


@pytest.mark.parametrize("kv_bits", [None, 8, 4])
def test_dispatched_path_matches_reference_bitwise(packed_setup, kv_bits):
    cfg, packed = packed_setup
    qlm = QuantizedDenseLM(cfg, block_size=16, kv_bits=kv_bits)
    pre_k, dec_k, cache_k = _run(qlm, packed, kernels=True)
    pre_r, dec_r, cache_r = _run(qlm, packed, kernels=False)
    np.testing.assert_array_equal(pre_k, pre_r)
    np.testing.assert_array_equal(dec_k, dec_r)
    # the cache state (including integer codes for kv_bits) matches too
    for (pk, lk), (pr, lr) in zip(
            jax.tree_util.tree_leaves_with_path(cache_k),
            jax.tree_util.tree_leaves_with_path(cache_r)):
        assert pk == pr
        np.testing.assert_array_equal(np.asarray(lk), np.asarray(lr))


def test_prefill_matches_stepwise_decode(packed_setup):
    """Causal prefill must produce the same per-position logits and cache
    as feeding the prompt token by token."""
    cfg, packed = packed_setup
    qlm = QuantizedDenseLM(cfg, block_size=16)
    cache = qlm.init_cache(1, 16)
    pre, _ = qlm.prefill(packed, jnp.asarray([TOKENS], jnp.int32), cache)
    cache = qlm.init_cache(1, 16)
    for i, t in enumerate(TOKENS):
        step, cache = qlm.decode_step(
            packed, jnp.asarray([[t]], jnp.int32), cache,
            jnp.asarray(i, jnp.int32))
        np.testing.assert_array_equal(np.asarray(pre[:, i]), np.asarray(step))


@pytest.mark.parametrize("kv_bits", [None, 8, 4])
def test_paged_attention_dispatch_matches_reference_bitwise(kv_bits):
    """`ops.paged_attention` under kernels (interpret mode here) and under
    `use_kernels(False)` must agree bit for bit — the reference replays
    the kernel's exact page walk (same shared helpers, same op order), the
    contract the engine's kernels-on/off equivalence test builds on."""
    rng = np.random.default_rng(3)
    b, s, kh, g, dh, t, n_cols, n_pages = 2, 4, 2, 2, 32, 8, 3, 7
    q = jnp.asarray(rng.standard_normal((b, s, kh * g, dh)), jnp.float32)
    bt = jnp.asarray(rng.permutation(np.arange(1, n_pages))[:b * n_cols]
                     .reshape(b, n_cols), jnp.int32)
    qpos = jnp.asarray([[9 + j for j in range(s)],
                        [14 + j for j in range(s)]], jnp.int32)
    shape = (n_pages, t, kh, dh)
    if kv_bits is None:
        kv = {"k": jnp.asarray(rng.standard_normal(shape), jnp.float32),
              "v": jnp.asarray(rng.standard_normal(shape), jnp.float32)}
    else:
        off, levels = 2 ** (kv_bits - 1), 2 ** kv_bits - 1
        kv = {
            "k": jnp.asarray(rng.integers(0, levels + 1, shape) - off,
                             jnp.int8),
            "v": jnp.asarray(rng.integers(0, levels + 1, shape) - off,
                             jnp.int8),
            "k_scale": jnp.asarray(rng.uniform(0.02, 0.2,
                                               (n_pages, t, kh, 1)),
                                   jnp.float32),
            "v_scale": jnp.asarray(rng.uniform(0.02, 0.2,
                                               (n_pages, t, kh, 1)),
                                   jnp.float32),
            "k_zero": jnp.asarray(
                np.round(rng.uniform(-12, 2, (n_pages, t, kh, 1))),
                jnp.float32),
            "v_zero": jnp.asarray(
                np.round(rng.uniform(-12, 2, (n_pages, t, kh, 1))),
                jnp.float32),
        }
    outs = {}
    for enabled in (True, False):
        with kops.use_kernels(enabled):
            outs[enabled] = np.asarray(kops.paged_attention(
                q, kv, bt, qpos, rope_theta=500000.0, kv_bits=kv_bits,
                kv_group=dh if kv_bits else None))
    np.testing.assert_array_equal(outs[True], outs[False])


def test_decode_uses_dispatch_not_ref():
    """The serving module must go through the ops dispatch layer only —
    no direct kernels.ref calls on the hot path."""
    import ast
    import inspect

    import repro.serve.quantized as SQ

    tree = ast.parse(inspect.getsource(SQ))
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                assert not a.name.endswith("kernels.ref"), \
                    "serve.quantized imports kernels.ref directly"
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            names = [a.name for a in node.names]
            assert not ("kernels" in mod and "ref" in names), \
                "serve.quantized imports kernels.ref directly"
            assert not mod.endswith("kernels.ref"), \
                "serve.quantized imports kernels.ref directly"
