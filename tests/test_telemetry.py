"""Telemetry: metrics primitives, trace well-formedness, schema
validation, and — the acceptance-critical part — bit-path neutrality:
turning tracing and quality probes on must not change a single generated
token or logit on any serving path.
"""
import importlib.util
import json
import math
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.transformer import build_model
from repro.serve.engine import (EngineRequest, SamplingParams, ServeEngine,
                                as_servable)
from repro.serve.quantized import QuantizedDenseLM, pack_dense_params
from repro.serve.telemetry import (PROBE_STATS, SCHEMA_VERSION, Histogram,
                                   MetricsRegistry, QualityProbes, Tracer,
                                   validate_snapshot, validate_trace)
from repro.serve.telemetry.metrics import Counter

PROMPTS = [[3, 14, 15, 92, 6], [53, 58, 9], [7, 9, 3, 23, 84, 62]]
MAX_NEW = 3


@pytest.fixture(scope="module")
def stack():
    """bf16 + packed-int4 adapters over one tiny dense config (no PTQ:
    the telemetry tests need the serving paths, not quantizer quality)."""
    cfg = get_config("llama3-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    packed = pack_dense_params(params, cfg)
    return cfg, model, params, packed


def _run(adapter, *, prompts=PROMPTS, **kw):
    kw.setdefault("n_pages", 33)
    kw.setdefault("page_size", 8)
    kw.setdefault("max_seqs", 2)
    kw.setdefault("prefill_chunk", 4)
    eng = ServeEngine(adapter, record_logits=True, **kw)
    for rid, p in enumerate(prompts):
        eng.submit(EngineRequest(rid=rid, prompt=list(p),
                                 sampling=SamplingParams(max_new=MAX_NEW)))
    done = {r.rid: r for r in eng.run()}
    assert len(done) == len(prompts)
    return eng, done


# ----------------------------------------------------------------------
# metrics primitives
# ----------------------------------------------------------------------

def test_histogram_bucket_boundaries():
    h = Histogram(base=1e-6, growth=2.0, n_buckets=40)
    # bucket 0 = [0, base), bucket i = [base·g^(i-1), base·g^i)
    assert h.bucket_index(0.0) == 0
    assert h.bucket_index(0.999e-6) == 0
    assert h.bucket_index(1e-6) == 1          # boundary is inclusive below
    assert h.bucket_index(1.999e-6) == 1
    assert h.bucket_index(2e-6) == 2
    assert h.bucket_index(4e-6) == 3
    assert h.bucket_index(1e12) == 39         # open-ended last bucket
    assert h.upper(0) == h.lower(1) == 1e-6
    assert h.upper(3) == h.lower(4) == 8e-6
    assert math.isinf(h.upper(39))
    for i in range(1, 39):                    # boundaries classify exactly
        assert h.bucket_index(h.lower(i)) == i


def _check_quantile_property(h, vals, q):
    """The estimate must land in the bucket holding the nearest-rank
    sample (so it is within one growth factor of the exact statistic) and
    inside the observed [min, max]."""
    est = h.quantile(q)
    rank = max(1, math.ceil(q * len(vals)))
    sample = sorted(vals)[rank - 1]
    b = h.bucket_index(sample)
    assert h.lower(b) <= est <= min(h.upper(b), max(vals))
    assert min(vals) <= est <= max(vals)


def test_quantile_within_bucket_of_nearest_rank():
    rng = np.random.default_rng(0)
    for _ in range(20):
        n = int(rng.integers(1, 200))
        vals = np.exp(rng.normal(-8, 4, size=n)).tolist()  # µs..hours
        h = Histogram()
        for v in vals:
            h.observe(v)
        for q in (0.0, 0.5, 0.95, 0.99, 1.0):
            _check_quantile_property(h, vals, q)


def test_quantile_property_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.lists(st.floats(min_value=0.0, max_value=1e9,
                                  allow_nan=False), min_size=1,
                        max_size=100),
               st.sampled_from([0.5, 0.9, 0.95, 0.99]))
    @hyp.settings(deadline=None, max_examples=200)
    def check(vals, q):
        h = Histogram()
        for v in vals:
            h.observe(v)
        _check_quantile_property(h, vals, q)

    check()


def test_histogram_merge_is_exact():
    a, b, both = Histogram(), Histogram(), Histogram()
    rng = np.random.default_rng(1)
    va, vb = rng.exponential(1e-3, 50), rng.exponential(10.0, 70)
    for v in va:
        a.observe(v)
        both.observe(v)
    for v in vb:
        b.observe(v)
        both.observe(v)
    a.merge(b)
    assert a.counts == both.counts and a.count == both.count
    assert a.min == both.min and a.max == both.max
    assert a.sum == pytest.approx(both.sum)
    with pytest.raises(ValueError):
        a.merge(Histogram(base=1e-3))          # config mismatch


def test_counter_monotonic_and_registry_reset():
    reg = MetricsRegistry()
    c = reg.counter("engine.steps")
    with pytest.raises(ValueError):
        c.inc(-1)
    c.inc(3)
    h = reg.histogram("engine.step.wall_s")
    h.observe(0.5)
    reg.reset()
    # identity survives the reset (hot-loop callers hold the instrument)
    assert reg.counter("engine.steps") is c and c.value == 0
    assert h.count == 0 and math.isinf(h.min)


def test_registry_merge_multi_host():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("engine.steps").inc(2)
    b.counter("engine.steps").inc(5)
    b.gauge("engine.queue.depth").set(7)
    b.histogram("engine.step.wall_s").observe(1.0)
    a.merge(b)
    assert a.counter("engine.steps").value == 7
    assert a.gauge("engine.queue.depth").value == 7
    assert a.histogram("engine.step.wall_s").count == 1


# ----------------------------------------------------------------------
# trace well-formedness
# ----------------------------------------------------------------------

def test_tracer_emits_valid_chrome_trace(tmp_path):
    tr = Tracer()
    tr.begin("request", pid=2, tid=1)
    tr.begin("queued", pid=2, tid=1)
    tr.end("queued", pid=2, tid=1)
    tr.instant("alloc_pages", pid=2, tid=1, args={"pages": 2})
    tr.complete("dispatch.decode", tr.ts(), 12.5)
    tr.end("request", pid=2, tid=1)
    n = validate_trace(tr.to_dict())
    assert n == len(tr.events)
    path = tmp_path / "t.json"
    tr.save(str(path))
    with open(path) as f:
        obj = json.load(f)                    # round-trips as plain JSON
    assert validate_trace(obj) == n
    # ts are µs on one monotonic clock: B before E for every span
    evs = [e for e in obj["traceEvents"] if e["ph"] in "BE"]
    assert evs[0]["ts"] <= evs[-1]["ts"]


@pytest.mark.parametrize("events,msg", [
    ([{"name": "a", "ph": "E", "ts": 1.0, "pid": 1, "tid": 0}],
     "without an open"),
    ([{"name": "a", "ph": "B", "ts": 1.0, "pid": 1, "tid": 0},
      {"name": "b", "ph": "E", "ts": 2.0, "pid": 1, "tid": 0}],
     "must nest"),
    ([{"name": "a", "ph": "B", "ts": 1.0, "pid": 1, "tid": 0}],
     "unclosed"),
    ([{"name": "a", "ph": "X", "ts": 1.0, "dur": -5, "pid": 1, "tid": 0}],
     "invalid dur"),
    ([{"name": "a", "ph": "B", "ts": -1.0, "pid": 1, "tid": 0}],
     "invalid ts"),
    ([{"ph": "B", "ts": 1.0, "pid": 1, "tid": 0}], "missing 'name'"),
])
def test_validate_trace_rejects_malformed(events, msg):
    with pytest.raises(ValueError, match=msg):
        validate_trace({"traceEvents": events})


# ----------------------------------------------------------------------
# schema validation
# ----------------------------------------------------------------------

def test_snapshot_schema_rejects_unknown_and_missing(stack):
    cfg, model, params, _ = stack
    eng, _ = _run(as_servable(model, params))
    snap = eng.metrics_snapshot()
    validate_snapshot(snap)

    bad = json.loads(json.dumps(snap))
    bad["counters"]["engine.typo_metric"] = 1
    with pytest.raises(ValueError, match="unknown counter"):
        validate_snapshot(bad)

    bad = json.loads(json.dumps(snap))
    del bad["histograms"]["engine.step.wall_s"]
    with pytest.raises(ValueError, match="missing histogram"):
        validate_snapshot(bad)

    bad = json.loads(json.dumps(snap))
    bad["schema_version"] = SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema_version"):
        validate_snapshot(bad)

    bad = json.loads(json.dumps(snap))
    bad["histograms"]["engine.step.wall_s"]["count"] += 1
    with pytest.raises(ValueError, match="inconsistent"):
        validate_snapshot(bad)


# ----------------------------------------------------------------------
# engine integration
# ----------------------------------------------------------------------

def test_engine_snapshot_and_trace_valid(stack):
    cfg, model, params, _ = stack
    tr = Tracer()
    eng, done = _run(as_servable(model, params), tracer=tr)
    snap = eng.metrics_snapshot()
    validate_snapshot(snap)
    validate_trace(tr.to_dict())
    c = snap["counters"]
    assert c["engine.requests.submitted"] == len(PROMPTS)
    assert c["engine.requests.finished"] == len(PROMPTS)
    assert c["engine.generated_tokens"] \
        == sum(len(r.generated) for r in done.values()) \
        == len(PROMPTS) * MAX_NEW
    assert c["engine.prefill_tokens"] == sum(len(p) for p in PROMPTS)
    assert 0 < c["engine.pages_walked"] < c["engine.pages_walked_dense"]
    # back-compat property views read the same registry counters
    assert eng.n_steps == c["engine.steps"] > 0
    assert eng.pages_walked == c["engine.pages_walked"]
    g = snap["gauges"]
    assert g["engine.pages.in_use"] == 0           # all released
    assert g["engine.pages.peak_in_use"] > 0
    assert g["engine.pages.scrubbed"] > 0
    h = snap["histograms"]
    assert h["engine.step.wall_s"]["count"] == c["engine.steps"]
    assert h["engine.decode.token_latency_s"]["count"] \
        == c["engine.generated_tokens"]
    assert h["engine.request.e2e_s"]["count"] == len(PROMPTS)
    # the kernel dispatch tallies were mirrored in
    assert any(k.startswith("kernels.dispatch.") for k in c)
    # every fused dispatch left an "X" event; every request a lifecycle
    evs = tr.to_dict()["traceEvents"]
    assert sum(e["ph"] == "X" for e in evs) > 0
    assert sum(e["ph"] == "B" and e["name"] == "request" for e in evs) \
        == len(PROMPTS)


def test_tracing_is_bit_path_neutral(stack):
    """Same tokens AND bit-identical recorded logits with tracing on."""
    cfg, model, params, _ = stack
    _, plain = _run(as_servable(model, params))
    _, traced = _run(as_servable(model, params), tracer=Tracer())
    for rid in plain:
        assert traced[rid].generated == plain[rid].generated
        for a, b in zip(traced[rid].step_logits, plain[rid].step_logits):
            assert np.array_equal(a, b)


def test_probes_are_bit_path_neutral_int4(stack):
    """The probe variant of the fused forward (barrier-isolated side
    computation) must not perturb the integer path: greedy tokens and
    logits bit-identical, and the per-layer quality stats land in the
    registry."""
    cfg, model, params, packed = stack
    qlm = QuantizedDenseLM(cfg, block_size=16)
    _, plain = _run(as_servable(qlm, packed))
    probes = QualityProbes(every_k=2)
    eng, probed = _run(as_servable(qlm, packed), quality_probes=probes,
                       tracer=Tracer())
    for rid in plain:
        assert probed[rid].generated == plain[rid].generated
        for a, b in zip(probed[rid].step_logits, plain[rid].step_logits):
            assert np.array_equal(a, b)
    snap = eng.metrics_snapshot()
    validate_snapshot(snap)
    n_probed = snap["counters"]["quality.probe_dispatches"]
    assert n_probed > 0
    for stat in PROBE_STATS:
        h = snap["histograms"][f"quality.{stat}"]
        assert h["count"] == n_probed * cfg.n_layers
        for layer in range(cfg.n_layers):
            assert f"quality.layer{layer:02d}.{stat}" in snap["gauges"]
    # probe physics sanity: imbalance >= 1 by construction, saturation a
    # rate in [0, 1]
    assert snap["histograms"]["quality.l1_imbalance_post"]["min"] >= 1.0
    assert 0.0 <= snap["histograms"]["quality.sat_rate"]["max"] <= 1.0


def test_probes_rejected_on_dense_adapter(stack):
    cfg, model, params, _ = stack
    with pytest.raises(ValueError, match="quality probes"):
        ServeEngine(as_servable(model, params), n_pages=33, page_size=8,
                    quality_probes=QualityProbes())


def test_reset_metrics_gives_fresh_window(stack):
    """A second run() on the same engine must not accumulate counters
    across runs once reset_metrics() marks the window boundary."""
    cfg, model, params, _ = stack
    eng, _ = _run(as_servable(model, params))
    first = eng.metrics_snapshot()["counters"]
    eng.reset_metrics()
    zero = eng.metrics_snapshot()
    validate_snapshot(zero)                  # still schema-complete
    assert zero["counters"]["engine.steps"] == 0
    assert zero["gauges"]["engine.pages.peak_in_use"] == 0
    for rid, p in enumerate(PROMPTS):
        eng.submit(EngineRequest(rid=100 + rid, prompt=list(p),
                                 sampling=SamplingParams(max_new=MAX_NEW)))
    eng.run()
    second = eng.metrics_snapshot()["counters"]
    for name in ("engine.steps", "engine.prefill_tokens",
                 "engine.decode_tokens", "engine.generated_tokens",
                 "engine.pages_walked", "engine.requests.finished"):
        assert second[name] == first[name], name


def test_register_slot_gauges_on_ssm(stack):
    cfg = get_config("mamba2-1.3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng, _ = _run(as_servable(model, params), prompts=PROMPTS[:1])
    snap = eng.metrics_snapshot()
    validate_snapshot(snap)
    g = snap["gauges"]
    assert g["engine.register_slots.capacity"] == eng.max_seqs
    assert g["engine.register_slots.peak_in_use"] == 1
    assert g["engine.register_slots.scrubbed"] == 1


# ----------------------------------------------------------------------
# bench row schema checks
# ----------------------------------------------------------------------

def _load_bench(name):
    root = pathlib.Path(__file__).resolve().parents[1] / "benchmarks"
    sys.path.insert(0, str(root))
    try:
        spec = importlib.util.spec_from_file_location(name,
                                                      root / f"{name}.py")
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod
    finally:
        sys.path.remove(str(root))


def test_kernel_bench_row_schema():
    kb = _load_bench("kernel_bench")
    good = {"op": "decode_ref", "decode_step_us": 12}
    kb._check_schema([good])
    with pytest.raises(ValueError, match="missing required field"):
        kb._check_schema([{"op": "decode_ref"}])
    with pytest.raises(ValueError, match="unknown op family"):
        kb._check_schema([{"op": "mystery_op", "value": 1}])
    with pytest.raises(ValueError, match="missing 'op'"):
        kb._check_schema([{"decode_step_us": 12}])
    kb._check_schema([{"op": "paged_attention_early_exit", "ctx": 64,
                       "kv_heads": 2, "q_heads": 4, "kv_splits": 1,
                       "page_size": 16, "batch": 4, "pages_per_step": 10,
                       "us_per_step": 1.0}])


def test_serve_bench_row_schema():
    sb = _load_bench("serve_bench")
    sb._check_schema([{"path": "x", "family": "dense", "tokens_per_s": 1}])
    with pytest.raises(ValueError, match="missing required field"):
        sb._check_schema([{"path": "x", "family": "dense"}])
