"""Allocator stress: random alloc/free/preempt/cancel interleavings.

Property-based hammering of `PageAllocator` / `RegisterAllocator` — the
host-side bookkeeping every engine robustness guarantee bottoms out in.
After *every* operation the structural invariants must hold:

  * free + in-use == capacity, and the free list mirrors its shadow set
    (no duplicates, no scratch, nothing outside the pool);
  * pages held by live sequences and the free list partition the pool —
    no page is both held and free, none vanishes;
  * a failed operation is a no-op: `MemoryError` on exhaustion and
    `ValueError` on a double/invalid free leave the allocator state
    byte-identical (the engine retries after preempting a victim, so a
    half-mutated allocator would corrupt every book downstream).

Runs under hypothesis when it is installed (minimized counterexamples);
otherwise the same executor is driven by seeded `numpy` random op
streams, so the property is exercised either way without adding a
dependency.
"""
import numpy as np
import pytest

from repro.serve.engine import PageAllocator, RegisterAllocator
from repro.serve.engine.pages import SCRATCH_PAGE, SCRATCH_SLOT

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N_PAGES = 12   # small pool → exhaustion and re-use happen constantly
N_SLOTS = 5

# op stream vocabulary: (kind, amount)
#   0 = admit: allocate `amount` pages for a new sequence
#   1 = grow: allocate `amount` more pages for a random live sequence
#   2 = release/cancel: free every page of a random live sequence
#   3 = preempt: same release path, but the sequence stays eligible to
#       be re-admitted by a later admit op (allocator-level identical)
#   4 = adversarial free: double-free a random free page (must raise)
#   5 = adversarial free: free the scratch page (must raise)
OPS = st.lists(st.tuples(st.integers(0, 5), st.integers(0, N_PAGES)),
               max_size=200) if HAVE_HYPOTHESIS else None


def _page_state(alloc):
    return (list(alloc._free), set(alloc._free_set), alloc.peak_in_use)


def _check_page_invariants(alloc, held):
    assert alloc.n_free + alloc.in_use == alloc.capacity
    assert len(alloc._free) == len(alloc._free_set) == len(set(alloc._free))
    assert alloc._free_set == set(alloc._free)
    held_pages = [p for pages in held.values() for p in pages]
    assert len(held_pages) == len(set(held_pages)), "page held twice"
    assert not (set(held_pages) & alloc._free_set), "page held AND free"
    universe = set(range(SCRATCH_PAGE + 1, alloc.n_pages))
    assert set(held_pages) | alloc._free_set == universe, "page vanished"
    assert alloc.peak_in_use >= alloc.in_use


def _exercise_pages(ops):
    alloc = PageAllocator(N_PAGES)
    held: dict[int, list[int]] = {}
    rng = np.random.default_rng(0)   # only for picking among live rids
    next_rid = 0
    for kind, amount in ops:
        before = _page_state(alloc)
        if kind == 0:
            try:
                pages = alloc.alloc(amount)
                held[next_rid] = pages
                next_rid += 1
            except MemoryError:
                assert amount > len(before[0])
                assert _page_state(alloc) == before, "exhaustion mutated"
        elif kind == 1 and held:
            rid = int(rng.choice(list(held)))
            try:
                held[rid].extend(alloc.alloc(amount))
            except MemoryError:
                assert _page_state(alloc) == before, "exhaustion mutated"
        elif kind in (2, 3) and held:
            rid = int(rng.choice(list(held)))
            alloc.free(held.pop(rid))
        elif kind == 4 and alloc.n_free:
            # double free: the page is already on the free list
            free_page = alloc._free[int(rng.integers(alloc.n_free))]
            with pytest.raises(ValueError, match="double/invalid"):
                alloc.free([free_page])
            assert _page_state(alloc) == before, "failed free mutated"
        elif kind == 5:
            with pytest.raises(ValueError, match="double/invalid"):
                alloc.free([SCRATCH_PAGE])
            assert _page_state(alloc) == before, "failed free mutated"
        _check_page_invariants(alloc, held)
    # drain: everything still held frees cleanly and the pool is whole
    for rid in list(held):
        alloc.free(held.pop(rid))
        _check_page_invariants(alloc, held)
    assert alloc.n_free == alloc.capacity and alloc.in_use == 0


def _exercise_registers(ops):
    alloc = RegisterAllocator(N_SLOTS)
    held: dict[int, int] = {}
    rng = np.random.default_rng(0)
    next_rid = 0
    for kind, _ in ops:
        before = (list(alloc._free), alloc.peak_in_use)
        if kind in (0, 1):
            try:
                held[next_rid] = alloc.alloc()
                next_rid += 1
            except MemoryError:
                assert alloc.n_free == 0
                assert (list(alloc._free), alloc.peak_in_use) == before
        elif kind in (2, 3) and held:
            rid = int(rng.choice(list(held)))
            alloc.free(held.pop(rid))
        elif kind == 4 and alloc.n_free:
            with pytest.raises(ValueError, match="double/invalid"):
                alloc.free(alloc._free[0])
            assert (list(alloc._free), alloc.peak_in_use) == before
        elif kind == 5:
            with pytest.raises(ValueError, match="double/invalid"):
                alloc.free(SCRATCH_SLOT)
            assert (list(alloc._free), alloc.peak_in_use) == before
        assert alloc.n_free + alloc.in_use == alloc.capacity
        assert len(alloc._free) == len(set(alloc._free))
        assert not (set(held.values()) & set(alloc._free))
    for rid in list(held):
        alloc.free(held.pop(rid))
    assert alloc.n_free == alloc.capacity


def _random_ops(seed, n=200):
    rng = np.random.default_rng(seed)
    return [(int(rng.integers(0, 6)), int(rng.integers(0, N_PAGES + 1)))
            for _ in range(n)]


if HAVE_HYPOTHESIS:

    @settings(max_examples=200, deadline=None)
    @given(OPS)
    def test_page_allocator_random_interleavings(ops):
        _exercise_pages(ops)

    @settings(max_examples=100, deadline=None)
    @given(OPS)
    def test_register_allocator_random_interleavings(ops):
        _exercise_registers(ops)

else:

    @pytest.mark.parametrize("seed", range(25))
    def test_page_allocator_random_interleavings(seed):
        _exercise_pages(_random_ops(seed))

    @pytest.mark.parametrize("seed", range(10))
    def test_register_allocator_random_interleavings(seed):
        _exercise_registers(_random_ops(seed))


# ----------------------------------------------------------------------
# refcounted sharing: share/unshare/cow/double-free interleavings
# ----------------------------------------------------------------------
#
# op stream vocabulary (kind, amount):
#   0 = admit: allocate `amount` pages for a new holder
#   1 = share: a new holder increfs a random prefix of a random live
#       holder's pages (the prefix-cache admission path)
#   2 = release: free a random holder's pages (shared ones merely drop a
#       reference; exclusive ones return to the free list)
#   3 = cow: a holder that shares a page replaces it — alloc 1 fresh
#       page, free the shared one (the copy-on-write divergence step)
#   4 = adversarial: free a page that is already free (must raise), and
#       free the same page twice in one batch (must raise)
#   5 = adversarial: incref a free page / the scratch page (must raise)


def _ref_state(alloc):
    return (list(alloc._free), set(alloc._free_set),
            dict(alloc._refs), alloc.peak_in_use)


def _check_ref_invariants(alloc, held):
    assert alloc.n_free + alloc.in_use == alloc.capacity
    assert alloc._free_set == set(alloc._free)
    assert len(alloc._free) == len(set(alloc._free))
    mult: dict[int, int] = {}
    for pages in held.values():
        for p in pages:
            mult[p] = mult.get(p, 0) + 1
    # every refcount equals the page's multiplicity across holders, the
    # shared-page gauge matches, and held ∪ free covers the pool exactly
    assert dict(alloc._refs) == mult, (alloc._refs, mult)
    for p in mult:
        assert alloc.refcount(p) == mult[p]
    assert alloc.n_shared == sum(1 for c in mult.values() if c > 1)
    assert not (set(mult) & alloc._free_set), "page held AND free"
    universe = set(range(SCRATCH_PAGE + 1, alloc.n_pages))
    assert set(mult) | alloc._free_set == universe, "page vanished"


def _exercise_refcounts(ops):
    alloc = PageAllocator(N_PAGES)
    held: dict[int, list[int]] = {}
    rng = np.random.default_rng(0)
    next_rid = 0
    for kind, amount in ops:
        before = _ref_state(alloc)
        if kind == 0:
            try:
                held[next_rid] = alloc.alloc(amount)
                next_rid += 1
            except MemoryError:
                assert amount > len(before[0])
                assert _ref_state(alloc) == before, "exhaustion mutated"
        elif kind == 1 and held:
            donor = held[int(rng.choice(list(held)))]
            prefix = donor[:1 + amount % max(len(donor), 1)] if donor else []
            alloc.incref(prefix)
            held[next_rid] = list(prefix)
            next_rid += 1
        elif kind == 2 and held:
            rid = int(rng.choice(list(held)))
            pages = held.pop(rid)
            freed = alloc.free(pages)
            # exactly the pages nobody else still holds came back
            still = {p for ps in held.values() for p in ps}
            assert set(freed) == set(pages) - still
        elif kind == 3 and held:
            rid = int(rng.choice(list(held)))
            pages = held[rid]
            shared = [i for i, p in enumerate(pages)
                      if alloc.refcount(p) > 1]
            if shared:
                i = shared[amount % len(shared)]
                try:
                    fresh = alloc.alloc(1)[0]
                except MemoryError:
                    assert _ref_state(alloc) == before
                    continue
                freed = alloc.free([pages[i]])
                assert freed == []          # others still hold it
                pages[i] = fresh
        elif kind == 4 and alloc.n_free:
            free_page = alloc._free[int(rng.integers(alloc.n_free))]
            with pytest.raises(ValueError, match="double/invalid"):
                alloc.free([free_page])
            assert _ref_state(alloc) == before, "failed free mutated"
            dup = [p for ps in held.values() for p in ps][:1]
            if dup:
                with pytest.raises(ValueError, match="double/invalid"):
                    alloc.free(dup + dup)   # intra-batch double free
                assert _ref_state(alloc) == before, "failed free mutated"
        elif kind == 5:
            targets = [SCRATCH_PAGE]
            if alloc.n_free:
                targets.append(alloc._free[0])
            for t in targets:
                with pytest.raises(ValueError, match="unallocated"):
                    alloc.incref([t])
                assert _ref_state(alloc) == before, "failed incref mutated"
        _check_ref_invariants(alloc, held)
    for rid in list(held):
        alloc.free(held.pop(rid))
        _check_ref_invariants(alloc, held)
    assert alloc.n_free == alloc.capacity and alloc.in_use == 0
    assert not alloc._refs


if HAVE_HYPOTHESIS:

    @settings(max_examples=200, deadline=None)
    @given(OPS)
    def test_refcount_random_interleavings(ops):
        _exercise_refcounts(ops)

else:

    @pytest.mark.parametrize("seed", range(25))
    def test_refcount_random_interleavings(seed):
        _exercise_refcounts(_random_ops(seed))


def test_free_returns_exactly_the_zero_refcount_pages():
    """The scrub contract: `free()` hands back precisely the pages whose
    last reference just dropped — never a still-shared page."""
    alloc = PageAllocator(N_PAGES)
    a = alloc.alloc(3)
    alloc.incref(a[:2])                 # second holder on a[0], a[1]
    assert alloc.n_shared == 2
    assert alloc.free(a) == [a[2]]      # only the exclusive page frees
    assert alloc.refcount(a[0]) == 1 and alloc.refcount(a[2]) == 0
    assert alloc.free(a[:2]) == a[:2]   # last holder → both free
    assert alloc.n_free == alloc.capacity


def test_exhaustion_is_a_clean_no_op():
    """The engine-facing contract in isolation: an alloc that cannot be
    satisfied raises MemoryError and changes nothing, so the scheduler
    can preempt a victim and retry on a consistent allocator."""
    alloc = PageAllocator(N_PAGES)
    got = alloc.alloc(5)
    before = _page_state(alloc)
    with pytest.raises(MemoryError):
        alloc.alloc(N_PAGES)
    assert _page_state(alloc) == before
    alloc.free(got)
    assert alloc.n_free == alloc.capacity
