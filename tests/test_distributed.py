"""Distribution-layer tests: sharding-rule unit tests (mesh-free) plus a
subprocess smoke of the real dry-run machinery on an 8-device host mesh
(device count must be set before jax initializes, hence the subprocess).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_spec_rules():
    """Spec rules are pure functions of (name, ndim) — verify key layouts
    without touching jax device state (mesh mocked)."""
    from repro.distributed import shardings as SH

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((4, 2))

    mesh = FakeMesh()
    assert tuple(SH.param_spec("embed", 2, mesh, zero3=False)) == \
        ("model", None)
    assert tuple(SH.param_spec("layers/attn/wq", 3, mesh, zero3=False)) == \
        (None, None, "model")
    assert tuple(SH.param_spec("layers/attn/wo", 3, mesh, zero3=False)) == \
        (None, "model", None)
    assert tuple(SH.param_spec("layers/attn/wq", 3, mesh, zero3=True)) == \
        (None, "data", "model")
    # MoE expert weights: experts on model, ZeRO dim on data
    assert tuple(SH.param_spec("layers/moe/w_gate", 4, mesh, zero3=True)) \
        == (None, "model", "data", None)
    assert tuple(SH.param_spec("layers/norm/scale", 2, mesh,
                               zero3=True)) == (None, None)


def test_fit_replicates_nondivisible():
    from jax.sharding import PartitionSpec as P
    from repro.distributed import shardings as SH

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((4, 2))

    mesh = FakeMesh()
    spec = SH._fit(P("model", "data"), (7, 8), mesh)   # 7 % 2 != 0
    assert tuple(spec) == (None, "data")


def test_cache_spec_seq_fallback():
    from repro.distributed import shardings as SH

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((4, 16))

    mesh = FakeMesh()
    # kv heads 8 % 16 != 0 → sequence-sharded cache
    spec = SH.cache_spec("layers/k", (16, 8, 32768, 8, 64), mesh)
    assert tuple(spec) == (None, "data", "model", None, None)
    # kv heads 32 % 16 == 0 → head-sharded cache
    spec = SH.cache_spec("layers/k", (16, 8, 32768, 32, 64), mesh)
    assert tuple(spec) == (None, "data", None, "model", None)


SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.distributed import shardings as SH
    from repro.distributed.context import mesh_context
    from repro.models.transformer import build_model
    from repro.optim import adamw
    from repro.train.step import TrainConfig, make_train_step

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("llama3-1b").reduced(n_layers=2, d_model=64, vocab=128,
                                          n_heads=4, n_kv_heads=2,
                                          head_dim=16, d_ff=128)
    model = build_model(cfg)
    with mesh_context(mesh):
        params = model.init(jax.random.PRNGKey(0))
        pshard = SH.param_shardings(mesh, params, cfg.name)
        params = jax.tree.map(jax.device_put, params, pshard)
        opt_cfg = adamw.AdamWConfig(lr=1e-3)
        opt = adamw.init_state(opt_cfg, params)
        oshard = SH.opt_state_shardings(mesh, opt, params, cfg.name)
        opt = jax.device_put(opt, oshard) if False else jax.tree.map(
            jax.device_put, opt,
            {"step": oshard["step"], "m": oshard["m"], "v": oshard["v"]})
        batch = {
            "tokens": jnp.zeros((8, 16), jnp.int32),
            "labels": jnp.zeros((8, 16), jnp.int32),
        }
        bshard = SH.batch_shardings(mesh, batch)
        batch = jax.tree.map(jax.device_put, batch, bshard)
        step = jax.jit(make_train_step(model, opt_cfg,
                                       TrainConfig(num_microbatches=2,
                                                   remat=True),
                                       param_shardings=pshard),
                       in_shardings=(pshard, oshard, bshard),
                       out_shardings=(pshard, oshard, None))
        params, opt, metrics = step(params, opt, batch)
        loss1 = float(metrics["loss"])
        params, opt, metrics = step(params, opt, batch)
        print(json.dumps({"loss1": loss1, "loss2": float(metrics["loss"]),
                          "n_dev": jax.device_count()}))
""")


@pytest.mark.slow
def test_sharded_train_step_executes_on_8_devices():
    """Actually EXECUTE (not just compile) a sharded, microbatched,
    rematerialized train step on 8 host devices."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["n_dev"] == 8
    assert np.isfinite(rec["loss1"]) and np.isfinite(rec["loss2"])
    assert rec["loss2"] < rec["loss1"] + 1.0  # sane optimization step
