"""Paged-attention kernel vs the gather+dense oracle.

The kernel is flash-decoding shaped — grid `(batch, kv_head_block,
q_block, kv_split, page_column)`, split-K partials merged by an LSE
combine kernel, ragged early-exit on scalar-prefetched used-page counts —
while the *independent* oracle gathers the pages into a contiguous slab
(`pages.gather_pages` arithmetic) and runs plain-softmax causal attention,
the exact data path the kernel replaced. Swept over page sizes, ragged
per-sequence lengths, GQA group sizes, `(q_block, kv_splits, head_block)`
tilings, and all three KV page formats (bf16-style float pages with
post-RoPE K, int8/int4 code pages with per-(position, head) scale/zero
and pre-RoPE K rotated after dequant). The split/combine reduction order
and the early-exit are additionally pinned bitwise: dispatch-vs-reference
for a non-trivial split config, and trimmed-pad-column no-ops.
"""
import math
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.models import layers as L

B, S_CHUNK, KH, G, DH = 3, 4, 2, 2, 32
H = KH * G


def _make_pool(rng, fmt, n_pages, t, kh=KH):
    shape = (n_pages, t, kh, DH)
    if fmt == "float":
        return {"k": jnp.asarray(rng.standard_normal(shape), jnp.float32),
                "v": jnp.asarray(rng.standard_normal(shape), jnp.float32)}
    bits = {"int8": 8, "int4": 4}[fmt]
    off, levels = 2 ** (bits - 1), 2 ** bits - 1

    def codes():
        return jnp.asarray(
            rng.integers(0, levels + 1, shape) - off, jnp.int8)

    def aux(lo, hi):
        return jnp.asarray(rng.uniform(lo, hi, (n_pages, t, kh, 1)),
                           jnp.float32)

    return {"k": codes(), "v": codes(),
            "k_scale": aux(0.02, 0.2), "v_scale": aux(0.02, 0.2),
            "k_zero": jnp.round(aux(-12.0, 2.0)),
            "v_zero": jnp.round(aux(-12.0, 2.0))}


def _dequant(codes, scale, zero, bits):
    off = 2 ** (bits - 1)
    return scale * (codes.astype(jnp.float32) + off + zero)


def _oracle(q, kv, bt, qpos, *, kv_bits, rope_theta):
    """Gather-to-slab + plain-softmax causal attention (the pre-kernel
    data path, written independently of the kernel helpers)."""
    b, s, h = q.shape[:3]
    t, kh = kv["k"].shape[1], kv["k"].shape[2]
    g = h // kh
    sk = bt.shape[1] * t
    k = kv["k"][bt].reshape(b, sk, kh, DH)
    v = kv["v"][bt].reshape(b, sk, kh, DH)
    if kv_bits is not None:
        ks = kv["k_scale"][bt].reshape(b, sk, kh, 1)
        kz = kv["k_zero"][bt].reshape(b, sk, kh, 1)
        vs = kv["v_scale"][bt].reshape(b, sk, kh, 1)
        vz = kv["v_zero"][bt].reshape(b, sk, kh, 1)
        k = _dequant(k, ks, kz, kv_bits)
        v = _dequant(v, vs, vz, kv_bits)
        kpos = jnp.broadcast_to(jnp.arange(sk)[None], (b, sk))
        k = L.apply_rope(k, kpos, rope_theta)
    qg = q.astype(jnp.float32).reshape(b, s, kh, g, DH)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                        k.astype(jnp.float32)) / math.sqrt(DH)
    valid = jnp.arange(sk)[None, None, :] <= qpos[:, :, None]
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, DH)


def _ragged_setup(rng, page_size, *, s):
    """Per-sequence ragged lengths → block tables (distinct pages, scratch
    padded) and query positions for an s-token chunk ending the context."""
    lengths = [page_size + 3, 3 * page_size, 2 * page_size - 1]
    n_cols = max(-(-n // page_size) for n in lengths)
    n_pages = 1 + sum(-(-n // page_size) for n in lengths)
    perm = rng.permutation(np.arange(1, n_pages)).tolist()
    bt = []
    for n in lengths:
        need = -(-n // page_size)
        row = [perm.pop() for _ in range(need)]
        bt.append(row + [0] * (n_cols - need))
    bt = jnp.asarray(bt, jnp.int32)
    qpos = jnp.asarray([[n - s + j for j in range(s)] for n in lengths],
                       jnp.int32)
    return lengths, n_pages, bt, qpos


@pytest.mark.parametrize("page_size", [8, 16])
@pytest.mark.parametrize("fmt,kv_bits", [("float", None), ("int8", 8),
                                         ("int4", 4)])
@pytest.mark.parametrize("s", [1, S_CHUNK])
def test_kernel_matches_gather_dense_oracle(page_size, fmt, kv_bits, s):
    # crc32, not hash(): string hashing is per-process randomized and would
    # make a failing draw unreproducible
    rng = np.random.default_rng(
        zlib.crc32(f"{page_size}-{fmt}-{s}".encode()))
    lengths, n_pages, bt, qpos = _ragged_setup(rng, page_size, s=s)
    kv = _make_pool(rng, fmt, n_pages, page_size)
    q = jnp.asarray(rng.standard_normal((B, s, H, DH)), jnp.float32)

    got = kops.paged_attention(q, kv, bt, qpos, rope_theta=500000.0,
                               kv_bits=kv_bits,
                               kv_group=DH if kv_bits else None)
    want = _oracle(q, kv, bt, qpos, kv_bits=kv_bits, rope_theta=500000.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_scratch_padded_columns_are_exact_noops():
    """Widening a block table with scratch columns (what decode batching
    does when one sequence is much longer) must not change any output bit:
    fully masked pages contribute exactly zero to the online softmax."""
    rng = np.random.default_rng(7)
    _, n_pages, bt, qpos = _ragged_setup(rng, 8, s=1)
    kv = _make_pool(rng, "float", n_pages, 8)
    q = jnp.asarray(rng.standard_normal((B, 1, H, DH)), jnp.float32)
    narrow = kops.paged_attention(q, kv, bt, qpos)
    wide = kops.paged_attention(
        q, kv, jnp.pad(bt, ((0, 0), (0, 5))), qpos)
    np.testing.assert_array_equal(np.asarray(narrow), np.asarray(wide))


def test_widening_is_exact_across_split_boundaries():
    """The regression the fixed-WIDTH split partitioning exists for:
    sequences whose live pages straddle a split boundary (5 and 6 live
    pages vs the 4-column split width) must keep bitwise-identical
    decode outputs as the table widens. Equal-width `ceil(n_cols /
    kv_splits)` partitioning moves the boundary when the table grows
    (5 cols → splits of 3, 8 cols → splits of 4), silently re-ordering
    a running sequence's online-softmax reduction every time a longer
    request is admitted and the engine's pow2 column bucket doubles."""
    rng = np.random.default_rng(31)
    t = 8
    lengths = [5 * t - 2, 3 * t, 6 * t - 1]        # 5, 3, 6 live pages
    n_cols = 6
    n_pages = 1 + sum(-(-n // t) for n in lengths)
    perm = rng.permutation(np.arange(1, n_pages)).tolist()
    bt = []
    for n in lengths:
        need = -(-n // t)
        bt.append([perm.pop() for _ in range(need)] + [0] * (n_cols - need))
    bt = jnp.asarray(bt, jnp.int32)
    qpos = jnp.asarray([[n - 1] for n in lengths], jnp.int32)
    kv = _make_pool(rng, "float", n_pages, t)
    q = jnp.asarray(rng.standard_normal((B, 1, H, DH)), jnp.float32)
    base = kops.paged_attention(q, kv, bt, qpos)
    for extra in (2, 10):                          # 8 and 16 columns
        wide = kops.paged_attention(
            q, kv, jnp.pad(bt, ((0, 0), (0, extra))), qpos)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(wide))


@pytest.mark.parametrize("fmt,kv_bits", [("float", None), ("int8", 8),
                                         ("int4", 4)])
@pytest.mark.parametrize("g", [1, 2, 4])
@pytest.mark.parametrize("q_block,kv_splits,head_block", [(1, 3, 1),
                                                          (2, 2, 2)])
def test_gqa_tiling_sweep_matches_oracle(fmt, kv_bits, g, q_block,
                                         kv_splits, head_block):
    """GQA group sizes (KH = 2 < H for g > 1) × explicit (q_block,
    kv_splits, head_block) tilings × all three KV formats against the
    gather+dense oracle: the flash-decoding grid axes and the split-K
    combine must be invisible to the math."""
    rng = np.random.default_rng(
        zlib.crc32(f"{fmt}-{g}-{q_block}-{kv_splits}-{head_block}".encode()))
    page_size, s = 8, 4
    lengths, n_pages, bt, qpos = _ragged_setup(rng, page_size, s=s)
    kv = _make_pool(rng, fmt, n_pages, page_size)
    q = jnp.asarray(rng.standard_normal((B, s, KH * g, DH)), jnp.float32)

    got = kops.paged_attention(
        q, kv, bt, qpos, jnp.asarray(lengths, jnp.int32),
        rope_theta=500000.0, kv_bits=kv_bits,
        kv_group=DH if kv_bits else None,
        q_block=q_block, kv_splits=kv_splits, head_block=head_block)
    want = _oracle(q, kv, bt, qpos, kv_bits=kv_bits, rope_theta=500000.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("fmt,kv_bits", [("float", None), ("int8", 8),
                                         ("int4", 4)])
def test_split_config_dispatch_matches_reference_bitwise(fmt, kv_bits):
    """A non-trivial flash-decoding config — multiple splits (with a
    ragged tail split), blocked queries AND blocked heads — must stay
    bit-for-bit between the interpret kernel and `use_kernels(False)`:
    the reference replays the identical split/combine reduction order,
    LSE combine included."""
    rng = np.random.default_rng(zlib.crc32(f"split-{fmt}".encode()))
    lengths, n_pages, bt, qpos = _ragged_setup(rng, 8, s=4)
    kv = _make_pool(rng, fmt, n_pages, 8)
    q = jnp.asarray(rng.standard_normal((B, 4, H, DH)), jnp.float32)
    lens = jnp.asarray(lengths, jnp.int32)

    outs = {}
    for enabled in (True, False):
        with kops.use_kernels(enabled):
            outs[enabled] = np.asarray(kops.paged_attention(
                q, kv, bt, qpos, lens, rope_theta=500000.0,
                kv_bits=kv_bits, kv_group=DH if kv_bits else None,
                q_block=2, kv_splits=2, head_block=2))
    np.testing.assert_array_equal(outs[True], outs[False])


@pytest.mark.parametrize("q_block,kv_splits,head_block", [
    (None, None, None), (1, 2, 2)])
def test_ragged_early_exit_is_exact(q_block, kv_splits, head_block):
    """The early-exit work reduction must be invisible bit for bit: a
    walk trimmed to each sequence's live pages (true `seq_lengths`)
    equals a forced full walk (`seq_lengths` = table capacity) exactly —
    a fully-masked page leaves m/l/acc bitwise unchanged, and an empty
    split carries exactly zero combine weight."""
    rng = np.random.default_rng(23)
    lengths, n_pages, bt, qpos = _ragged_setup(rng, 8, s=1)
    kv = _make_pool(rng, "float", n_pages, 8)
    q = jnp.asarray(rng.standard_normal((B, 1, H, DH)), jnp.float32)
    kw = dict(q_block=q_block, kv_splits=kv_splits, head_block=head_block)
    trimmed = kops.paged_attention(
        q, kv, bt, qpos, jnp.asarray(lengths, jnp.int32), **kw)
    full = kops.paged_attention(
        q, kv, bt, qpos, jnp.full((B,), bt.shape[1] * 8, jnp.int32), **kw)
    np.testing.assert_array_equal(np.asarray(trimmed), np.asarray(full))


def test_zero_length_rows_skip_the_whole_walk():
    """seq_lengths = 0 (a padded decode slot) skips every column: the
    row's output is exactly zero and — the part that matters — the other
    rows' outputs are untouched bit for bit."""
    rng = np.random.default_rng(29)
    lengths, n_pages, bt, qpos = _ragged_setup(rng, 8, s=1)
    kv = _make_pool(rng, "float", n_pages, 8)
    q = jnp.asarray(rng.standard_normal((B, 1, H, DH)), jnp.float32)
    base = kops.paged_attention(q, kv, bt, qpos,
                                jnp.asarray(lengths, jnp.int32))
    lens0 = jnp.asarray([lengths[0], 0, lengths[2]], jnp.int32)
    out = kops.paged_attention(q, kv, bt, qpos, lens0)
    assert np.all(np.asarray(out[1]) == 0.0)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(base[0]))
    np.testing.assert_array_equal(np.asarray(out[2]), np.asarray(base[2]))


def test_single_page_walk_tracks_plain_softmax_tightly():
    """One table column degenerates the online softmax to exp(x−max)/Σ —
    only the final normalisation order differs from the dense oracle
    (probs·V vs (p·V)/Σ), so the two must agree to f32 rounding."""
    rng = np.random.default_rng(11)
    kv = _make_pool(rng, "float", 4, 16)
    bt = jnp.asarray([[1], [2], [3]], jnp.int32)
    qpos = jnp.asarray([[15], [9], [4]], jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, 1, H, DH)), jnp.float32)
    got = kops.paged_attention(q, kv, bt, qpos)
    want = _oracle(q, kv, bt, qpos, kv_bits=None, rope_theta=None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)
