"""Paged-attention kernel vs the gather+dense oracle.

The kernel walks block tables with an online softmax; the *independent*
oracle gathers the pages into a contiguous slab (`pages.gather_pages`
arithmetic) and runs plain-softmax causal attention — the exact data path
the kernel replaced. Swept over page sizes, ragged per-sequence lengths,
and all three KV page formats (bf16-style float pages with post-RoPE K,
int8/int4 code pages with per-(position, head) scale/zero and pre-RoPE K
rotated after dequant).
"""
import math
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.models import layers as L

B, S_CHUNK, KH, G, DH = 3, 4, 2, 2, 32
H = KH * G


def _make_pool(rng, fmt, n_pages, t):
    shape = (n_pages, t, KH, DH)
    if fmt == "float":
        return {"k": jnp.asarray(rng.standard_normal(shape), jnp.float32),
                "v": jnp.asarray(rng.standard_normal(shape), jnp.float32)}
    bits = {"int8": 8, "int4": 4}[fmt]
    off, levels = 2 ** (bits - 1), 2 ** bits - 1

    def codes():
        return jnp.asarray(
            rng.integers(0, levels + 1, shape) - off, jnp.int8)

    def aux(lo, hi):
        return jnp.asarray(rng.uniform(lo, hi, (n_pages, t, KH, 1)),
                           jnp.float32)

    return {"k": codes(), "v": codes(),
            "k_scale": aux(0.02, 0.2), "v_scale": aux(0.02, 0.2),
            "k_zero": jnp.round(aux(-12.0, 2.0)),
            "v_zero": jnp.round(aux(-12.0, 2.0))}


def _dequant(codes, scale, zero, bits):
    off = 2 ** (bits - 1)
    return scale * (codes.astype(jnp.float32) + off + zero)


def _oracle(q, kv, bt, qpos, *, kv_bits, rope_theta):
    """Gather-to-slab + plain-softmax causal attention (the pre-kernel
    data path, written independently of the kernel helpers)."""
    b, s = q.shape[:2]
    t = kv["k"].shape[1]
    sk = bt.shape[1] * t
    k = kv["k"][bt].reshape(b, sk, KH, DH)
    v = kv["v"][bt].reshape(b, sk, KH, DH)
    if kv_bits is not None:
        ks = kv["k_scale"][bt].reshape(b, sk, KH, 1)
        kz = kv["k_zero"][bt].reshape(b, sk, KH, 1)
        vs = kv["v_scale"][bt].reshape(b, sk, KH, 1)
        vz = kv["v_zero"][bt].reshape(b, sk, KH, 1)
        k = _dequant(k, ks, kz, kv_bits)
        v = _dequant(v, vs, vz, kv_bits)
        kpos = jnp.broadcast_to(jnp.arange(sk)[None], (b, sk))
        k = L.apply_rope(k, kpos, rope_theta)
    qg = q.astype(jnp.float32).reshape(b, s, KH, G, DH)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                        k.astype(jnp.float32)) / math.sqrt(DH)
    valid = jnp.arange(sk)[None, None, :] <= qpos[:, :, None]
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, H, DH)


def _ragged_setup(rng, page_size, *, s):
    """Per-sequence ragged lengths → block tables (distinct pages, scratch
    padded) and query positions for an s-token chunk ending the context."""
    lengths = [page_size + 3, 3 * page_size, 2 * page_size - 1]
    n_cols = max(-(-n // page_size) for n in lengths)
    n_pages = 1 + sum(-(-n // page_size) for n in lengths)
    perm = rng.permutation(np.arange(1, n_pages)).tolist()
    bt = []
    for n in lengths:
        need = -(-n // page_size)
        row = [perm.pop() for _ in range(need)]
        bt.append(row + [0] * (n_cols - need))
    bt = jnp.asarray(bt, jnp.int32)
    qpos = jnp.asarray([[n - s + j for j in range(s)] for n in lengths],
                       jnp.int32)
    return lengths, n_pages, bt, qpos


@pytest.mark.parametrize("page_size", [8, 16])
@pytest.mark.parametrize("fmt,kv_bits", [("float", None), ("int8", 8),
                                         ("int4", 4)])
@pytest.mark.parametrize("s", [1, S_CHUNK])
def test_kernel_matches_gather_dense_oracle(page_size, fmt, kv_bits, s):
    # crc32, not hash(): string hashing is per-process randomized and would
    # make a failing draw unreproducible
    rng = np.random.default_rng(
        zlib.crc32(f"{page_size}-{fmt}-{s}".encode()))
    lengths, n_pages, bt, qpos = _ragged_setup(rng, page_size, s=s)
    kv = _make_pool(rng, fmt, n_pages, page_size)
    q = jnp.asarray(rng.standard_normal((B, s, H, DH)), jnp.float32)

    got = kops.paged_attention(q, kv, bt, qpos, rope_theta=500000.0,
                               kv_bits=kv_bits,
                               kv_group=DH if kv_bits else None)
    want = _oracle(q, kv, bt, qpos, kv_bits=kv_bits, rope_theta=500000.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_scratch_padded_columns_are_exact_noops():
    """Widening a block table with scratch columns (what decode batching
    does when one sequence is much longer) must not change any output bit:
    fully masked pages contribute exactly zero to the online softmax."""
    rng = np.random.default_rng(7)
    _, n_pages, bt, qpos = _ragged_setup(rng, 8, s=1)
    kv = _make_pool(rng, "float", n_pages, 8)
    q = jnp.asarray(rng.standard_normal((B, 1, H, DH)), jnp.float32)
    narrow = kops.paged_attention(q, kv, bt, qpos)
    wide = kops.paged_attention(
        q, kv, jnp.pad(bt, ((0, 0), (0, 5))), qpos)
    np.testing.assert_array_equal(np.asarray(narrow), np.asarray(wide))


def test_single_page_walk_tracks_plain_softmax_tightly():
    """One table column degenerates the online softmax to exp(x−max)/Σ —
    only the final normalisation order differs from the dense oracle
    (probs·V vs (p·V)/Σ), so the two must agree to f32 rounding."""
    rng = np.random.default_rng(11)
    kv = _make_pool(rng, "float", 4, 16)
    bt = jnp.asarray([[1], [2], [3]], jnp.int32)
    qpos = jnp.asarray([[15], [9], [4]], jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, 1, H, DH)), jnp.float32)
    got = kops.paged_attention(q, kv, bt, qpos)
    want = _oracle(q, kv, bt, qpos, kv_bits=None, rope_theta=None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)
