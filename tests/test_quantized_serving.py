"""Integer W4A4 serving path: packed weights + (optional) int4 KV cache
must track the fake-quant model and stay usable for generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import pipeline as PL
from repro.core.synthetic import inject_outlier_channels
from repro.models.transformer import build_model
from repro.serve.quantized import QuantizedDenseLM, pack_dense_params


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3-1b").reduced()
    model = build_model(cfg)
    params = inject_outlier_channels(model.init(jax.random.PRNGKey(0)))
    key = jax.random.PRNGKey(1)
    calib = [{"tokens": jax.random.randint(key, (4, 64), 0, cfg.vocab),
              "labels": jnp.zeros((4, 64), jnp.int32)}]
    res = PL.quantize_model(model, params, calib,
                            PL.preset("perq_star", block_size=16,
                                      rounding="rtn"))
    return cfg, model, params, res


def _teacher_forced(dec_fn, params, cache, tokens):
    """Feed a fixed token sequence; return per-step logits."""
    out = []
    for i, t in enumerate(tokens):
        logits, cache = dec_fn(params, jnp.asarray([[t]], jnp.int32), cache,
                               jnp.asarray(i, jnp.int32))
        out.append(np.asarray(logits[0], np.float32))
    return out


@pytest.mark.parametrize("kv_bits", [None, 8])
def test_integer_path_tracks_fake_quant(setup, kv_bits):
    """Teacher-forced stepwise comparison between the fake-quant evaluation
    model and the packed-int4 integer serving path. The bf16-cache variant
    must agree on argmax for most steps; the int8-KV variant is held to a
    strong per-step logits correlation (int4-KV on this untrained,
    outlier-injected model flips near-tied attention rows — its mechanism
    is validated by the error-bound test below; production int4-KV relies
    on the KIVI-style group scales plus a trained model's logit margins)."""
    cfg, model, params, res = setup
    qmodel = PL.build_quantized_model(model, res)
    qlm = QuantizedDenseLM(cfg, block_size=16, kv_bits=kv_bits)
    packed = pack_dense_params(res.params, cfg)

    seq = [3, 14, 15, 92, 6, 53, 58, 97, 9, 323]
    cache_fq = qmodel.init_cache(1, 32, dtype=jnp.float32)
    fq = _teacher_forced(lambda p, t, c, i: qmodel.decode_step(p, t, c, i),
                         res.params, cache_fq, seq)
    cache_q = qlm.init_cache(1, 32)
    qq = _teacher_forced(lambda p, t, c, i: qlm.decode_step(p, t, c, i),
                         packed, cache_q, seq)

    corrs = [np.corrcoef(a, b)[0, 1] for a, b in zip(fq, qq)]
    assert np.mean(corrs) >= 0.95, corrs
    if kv_bits is None:
        agree = np.mean([a.argmax() == b.argmax() for a, b in zip(fq, qq)])
        assert agree >= 0.7, agree


def test_packed_weights_roundtrip(setup):
    cfg, model, params, res = setup
    packed = pack_dense_params(res.params, cfg)
    # packed storage is ~4x smaller than bf16 for the projections
    orig = sum(np.prod(v.shape) * 2
               for k, v in jax.tree_util.tree_leaves_with_path(
                   res.params["layers"]["attn"]) if True) \
        if False else None
    w = res.params["layers"]["attn"]["wq"]
    p = packed["layers"]["attn"]["wq"]
    assert p["packed"].dtype == jnp.uint8
    assert p["packed"].shape == (w.shape[0], w.shape[1] // 2, w.shape[2])
    # dequantized packed weights match the fake-quant weights closely
    from repro.kernels.ref import int4_unpack
    deq = jax.vmap(int4_unpack)(p["packed"]).astype(jnp.float32) \
        * p["scale"][:, None, :]
    np.testing.assert_allclose(np.asarray(deq), np.asarray(w, np.float32),
                               atol=float(jnp.max(p["scale"])) * 0.51)


def test_int4_kv_cache_quantization_error_small(setup):
    cfg, model, params, res = setup
    qlm = QuantizedDenseLM(cfg, block_size=16, kv_bits=4)
    cache = qlm.init_cache(2, 16)
    one = jax.tree.map(lambda a: a[0], cache)
    k = jax.random.normal(jax.random.PRNGKey(2),
                          (2, 1, cfg.n_kv_heads, cfg.head_dim))
    v = jax.random.normal(jax.random.PRNGKey(3), k.shape)
    new = qlm._cache_write(one, k, v, jnp.asarray(3))
    kr, vr = qlm._cache_read(new)
    # int4 per-(position, head) scale: error ≤ scale/2 = absmax/14
    tol_k = float(jnp.max(jnp.abs(k))) / 14 + 1e-6
    tol_v = float(jnp.max(jnp.abs(v))) / 14 + 1e-6
    np.testing.assert_allclose(np.asarray(kr[:, 3]), np.asarray(k[:, 0]),
                               atol=tol_k)
    np.testing.assert_allclose(np.asarray(vr[:, 3]), np.asarray(v[:, 0]),
                               atol=tol_v)
