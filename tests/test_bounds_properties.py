"""Property tests (hypothesis) for the paper's Section-3 theory.

The propositions are deterministic inequalities — they must hold for EVERY
input vector, which is exactly what hypothesis probes.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import bounds, hadamard as hd
from repro.core import massdiff as md

jax.config.update("jax_enable_x64", False)


def vec(d, lo=-100.0, hi=100.0):
    return st.lists(
        st.floats(lo, hi, allow_nan=False, allow_infinity=False, width=32),
        min_size=d, max_size=d,
    ).map(lambda v: np.asarray(v, np.float32))


def _nonzero(x):
    return float(np.max(np.abs(x))) > 1e-6


@settings(max_examples=60, deadline=None)
@given(vec(64))
def test_prop31_full_vector_bound(xs):
    if not _nonzero(xs):
        return
    x = jnp.asarray(xs)
    xr = hd.fwht(x)
    lhs = float(jnp.max(jnp.abs(xr)))
    rhs = float(bounds.prop31_bound(x))
    assert lhs <= rhs * (1 + 1e-4) + 1e-5


@settings(max_examples=60, deadline=None)
@given(vec(64), st.sampled_from([4, 8, 16, 32]))
def test_prop32_block_bound(xs, b):
    if not _nonzero(xs):
        return
    x = jnp.asarray(xs)
    xr = hd.block_hadamard_transform(x, b)
    lhs = float(jnp.max(jnp.abs(xr)))
    rhs = float(bounds.prop32_bound(x, b))
    assert lhs <= rhs * (1 + 1e-4) + 1e-5


@settings(max_examples=60, deadline=None)
@given(vec(64), st.sampled_from([(8, 2), (8, 4), (16, 2), (4, 4)]))
def test_cor33_evolution(xs, bk):
    b_small, k = bk
    x = jnp.asarray(xs)
    z_big = float(bounds.zeta(x, b_small * k))
    z_small = float(bounds.cor33_rhs(x, b_small, k))
    assert z_big <= z_small * (1 + 1e-4) + 1e-5


@settings(max_examples=40, deadline=None)
@given(vec(64))
def test_delta_ranges(xs):
    if not _nonzero(xs):
        return
    x = jnp.asarray(xs)
    d = x.shape[-1]
    delta = float(bounds.mass_concentration(x))
    assert 1.0 / d - 1e-5 <= delta <= 1.0 + 1e-5
    dp = float(bounds.energy_concentration(x))
    assert 1.0 / math.sqrt(d) - 1e-4 <= dp <= 1.0 + 1e-4


@settings(max_examples=40, deadline=None)
@given(vec(64))
def test_sufficient_condition_guarantees_suppression(xs):
    """δ < 1/√d ⇒ ‖XR‖∞ < ‖X‖∞ (the Prop-3.1 guarantee)."""
    if not _nonzero(xs):
        return
    x = jnp.asarray(xs)
    d = x.shape[-1]
    delta = float(bounds.mass_concentration(x))
    if delta < bounds.sufficient_threshold_full(d) * (1 - 1e-3):
        ratio = float(bounds.suppression_ratio(x, hd.fwht(x)))
        assert ratio < 1.0 + 1e-4


def test_prop34_probabilistic_bound_monte_carlo():
    """Rademacher-sign resampling violates the 1−ε bound at most ~ε often."""
    rng = np.random.default_rng(0)
    d, b, eps, trials = 128, 16, 0.05, 400
    y = np.abs(rng.laplace(size=d)).astype(np.float32)
    violations = 0
    for _ in range(trials):
        s = rng.choice([-1.0, 1.0], size=d).astype(np.float32)
        x = jnp.asarray(s * y)
        xr = hd.block_hadamard_transform(x, b)
        lhs = float(jnp.max(jnp.abs(xr)))
        rhs = float(bounds.prop34_bound(x, b, eps, tight=True))
        violations += lhs > rhs
    assert violations / trials <= eps  # sub-Gaussian bounds are conservative


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([8, 16, 32]))
def test_massdiff_minimizes_prop32_bound_vs_identity(seed, b):
    """Permuting by MassDiff never increases the Prop-3.2 bound on the
    calibration mass profile (the quantity Alg. 1 greedily minimizes)."""
    rng = np.random.default_rng(seed)
    d = 128
    calib = rng.laplace(size=(32, d)).astype(np.float32) * \
        rng.uniform(0.1, 10.0, size=(1, d)).astype(np.float32)
    mass = md.coordinate_mass(calib)
    perm = md.massdiff(mass, b)
    before = mass.reshape(-1, b).sum(-1).max()
    after = mass[perm].reshape(-1, b).sum(-1).max()
    assert after <= before * (1 + 1e-9)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_permutations_are_bijections(seed):
    rng = np.random.default_rng(seed)
    d, b = 96, 16
    calib = rng.standard_normal((8, d)).astype(np.float32)
    for meth in ["identity", "random", "absmax", "zigzag", "massdiff"]:
        p = md.make_permutation(meth, calib, b, seed=seed)
        assert sorted(p.tolist()) == list(range(d)), meth


def test_perm_matrix_convention():
    rng = np.random.default_rng(3)
    d = 24
    perm = rng.permutation(d)
    x = rng.standard_normal((5, d)).astype(np.float32)
    P = md.perm_matrix(perm)
    np.testing.assert_allclose(x @ P, x[:, perm], atol=0)
    inv = md.invert(perm)
    np.testing.assert_allclose(x[:, perm][:, inv], x, atol=0)
