"""Paged-KV continuous-batching engine: equivalence + accounting.

The engine's block-table-native data path (in-forward page writes + the
paged-attention kernel walk) must be semantically invisible — for every
adapter backend (bf16 Model, fake-quant Model, packed-int4
`QuantizedDenseLM` with bf16/int8/int4 KV pages) the engine's greedy
generations must match the dense-cache path, chunked prefill must match
stepwise decode, mid-flight admission must not perturb running sequences,
and pages must never leak across requests.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import pipeline as PL
from repro.models.transformer import build_model
from repro.serve.engine import (EngineRequest, PageAllocator, SamplingParams,
                                ServeEngine, as_servable, pages_for)
from repro.serve.quantized import QuantizedDenseLM, pack_dense_params

MAX_NEW = 4
PROMPTS = [[3, 14, 15, 92, 6], [53, 58, 9], [7, 9, 3, 23, 84, 62, 43]]


@pytest.fixture(scope="module")
def stack():
    """cfg + params + PTQ result shared by every backend parametrization."""
    cfg = get_config("llama3-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                           0, cfg.vocab),
              "labels": jnp.zeros((2, 32), jnp.int32)}]
    res = PL.quantize_model(model, params, calib,
                            PL.preset("perq_star", block_size=16,
                                      rounding="rtn", cayley_steps=2))
    return cfg, model, params, res


def _adapter(stack, backend):
    cfg, model, params, res = stack
    if backend == "bf16":
        return as_servable(model, params)
    if backend == "fake_quant":
        return as_servable(PL.build_quantized_model(model, res), res.params,
                           name="fake-quant")
    kv_bits = {"int_kvbf16": None, "int_kv8": 8, "int_kv4": 4}[backend]
    qlm = QuantizedDenseLM(cfg, block_size=16, kv_bits=kv_bits)
    return as_servable(qlm, pack_dense_params(res.params, cfg))


def _dense_greedy(adapter, prompt, max_new):
    """The existing dense-cache serving path: whole-prompt prefill + a
    stepwise decode loop over one contiguous [1, max_len] cache."""
    cache = adapter.init_cache(1, 64)
    logits, cache = adapter.forward_chunk(
        adapter.params, jnp.asarray([prompt], jnp.int32), cache,
        jnp.asarray(0, jnp.int32))
    toks = [int(jnp.argmax(logits[0, -1]))]
    steps = [np.asarray(logits[0, -1], np.float32)]
    for j in range(max_new - 1):
        lg, cache = adapter.forward_chunk(
            adapter.params, jnp.asarray([[toks[-1]]], jnp.int32), cache,
            jnp.asarray(len(prompt) + j, jnp.int32))
        steps.append(np.asarray(lg[0, 0], np.float32))
        toks.append(int(jnp.argmax(lg[0, 0])))
    return toks, steps


def _engine_run(adapter, prompts, *, max_new=MAX_NEW, **kw):
    kw.setdefault("n_pages", 33)
    kw.setdefault("page_size", 8)
    kw.setdefault("max_seqs", 2)
    kw.setdefault("prefill_chunk", 4)
    eng = ServeEngine(adapter, record_logits=True, **kw)
    for rid, p in enumerate(prompts):
        eng.submit(EngineRequest(rid=rid, prompt=p,
                                 sampling=SamplingParams(max_new=max_new)))
    done = eng.run()
    assert len(done) == len(prompts)
    return eng, {r.rid: r for r in done}


@pytest.mark.parametrize("backend,min_corr", [
    ("bf16", 0.999),
    ("fake_quant", 0.999),
    ("int_kvbf16", 0.999),
    ("int_kv8", 0.95),
    ("int_kv4", 0.95),
])
def test_paged_engine_matches_dense_path(stack, backend, min_corr):
    """Acceptance: paged logits track the dense-cache path for all three
    adapter backends and every KV page format."""
    adapter = _adapter(stack, backend)
    _, done = _engine_run(adapter, PROMPTS)
    for rid, prompt in enumerate(PROMPTS):
        want_toks, want_logits = _dense_greedy(adapter, prompt, MAX_NEW)
        req = done[rid]
        assert req.generated == want_toks, (rid, req.generated, want_toks)
        for got, want in zip(req.step_logits, want_logits):
            assert np.corrcoef(got, want)[0, 1] >= min_corr


def test_chunked_prefill_matches_stepwise(stack):
    """Chunked prefill (4 tokens/chunk) ≡ one-token-at-a-time prefill:
    same tokens and near-identical per-step logits."""
    adapter = _adapter(stack, "bf16")
    _, chunked = _engine_run(adapter, PROMPTS, prefill_chunk=4)
    _, stepwise = _engine_run(adapter, PROMPTS, prefill_chunk=1)
    for rid in range(len(PROMPTS)):
        assert chunked[rid].generated == stepwise[rid].generated
        for a, b in zip(chunked[rid].step_logits, stepwise[rid].step_logits):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_midflight_admission_does_not_perturb(stack):
    """A sequence decoding while another is admitted and prefilled must
    produce exactly the logits it produces running alone."""
    adapter = _adapter(stack, "bf16")

    def run(with_second):
        eng = ServeEngine(adapter, n_pages=33, page_size=8, max_seqs=2,
                          prefill_chunk=4, record_logits=True)
        eng.submit(EngineRequest(rid=0, prompt=PROMPTS[0],
                                 sampling=SamplingParams(max_new=6)))
        out = []
        out += eng.step()
        out += eng.step()
        if with_second:
            eng.submit(EngineRequest(rid=1, prompt=PROMPTS[2],
                                     sampling=SamplingParams(max_new=2)))
        while eng.queue or eng.active:
            out += eng.step()
        return {r.rid: r for r in out}

    alone = run(False)
    mixed = run(True)
    assert 1 in mixed and mixed[1].done
    assert mixed[0].generated == alone[0].generated
    for a, b in zip(mixed[0].step_logits, alone[0].step_logits):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_page_accounting_no_leaks(stack):
    """Many short requests through a pool too small to hold them all at
    once: everything completes (admission queues on pages) and every page
    returns to the free list."""
    adapter = _adapter(stack, "bf16")
    prompts = [[(7 * i + j) % 500 for j in range(3 + i % 4)]
               for i in range(8)]
    # each request commits pages_for(prompt + max_new) = 1..2 pages of 8;
    # capacity 4 forces queueing behind page availability
    eng, done = _engine_run(adapter, prompts, n_pages=5, page_size=8,
                            max_seqs=2, max_new=3)
    assert eng.kv.allocator.n_free == eng.kv.allocator.capacity == 4
    assert not eng.kv.tables and not eng._committed
    assert all(len(done[i].generated) == 3 for i in range(len(prompts)))


def test_walked_pages_accounting(stack):
    """The scheduler's walked-pages counters must show the ragged
    early-exit doing strictly less work than the padded-batch ×
    full-table walk of the pre-flash-decode kernel (the benchmarks
    report exactly these counters)."""
    adapter = _adapter(stack, "bf16")
    eng, _ = _engine_run(adapter, PROMPTS)
    assert 0 < eng.pages_walked < eng.pages_walked_dense


def test_integer_kv_pages_round_trip(stack):
    """Integer KV pages carry codes + scale/zero: after a run the pool
    leaves keep the int8 code dtype and the engine still frees cleanly."""
    adapter = _adapter(stack, "int_kv4")
    eng, done = _engine_run(adapter, PROMPTS[:2])
    assert eng.kv.pool["k"].dtype == jnp.int8
    assert set(eng.kv.pool) == {"k", "v", "k_scale", "v_scale",
                                "k_zero", "v_zero"}
    assert eng.kv.allocator.n_free == eng.kv.allocator.capacity


def test_scheduler_dispatch_is_block_table_native():
    """Acceptance guard: the scheduler's decode/prefill dispatches must
    not gather pages into a slab or scatter rows back — the pool and the
    block tables go straight into `forward_chunk`, and the gather/scatter
    primitives survive only as the test oracle in `pages.py`."""
    import ast
    import inspect

    import repro.serve.engine.scheduler as SCH

    banned = {"gather_pages", "scatter_decode_rows", "scatter_prefill_rows"}
    for node in ast.walk(ast.parse(inspect.getsource(SCH))):
        if isinstance(node, ast.Name):
            assert node.id not in banned, f"scheduler references {node.id}"
        elif isinstance(node, ast.Attribute):
            assert node.attr not in banned, f"scheduler references {node.attr}"


def test_allocator_stress_many_frees():
    """Freeing thousands of pages must be cheap (the double-free guard is
    set-backed, not an O(n) list scan per page) and exact: every page
    returns, LIFO reuse order holds, and misuse still raises."""
    n = 4097
    alloc = PageAllocator(n)
    rng = np.random.default_rng(0)
    held = [alloc.alloc(64) for _ in range(64)]    # drain the pool
    assert alloc.n_free == 0
    order = rng.permutation(len(held))
    for i in order:
        alloc.free(held[i])
    assert alloc.n_free == alloc.capacity == n - 1
    assert sorted(p for chunk in held for p in chunk) == list(range(1, n))
    again = alloc.alloc(n - 1)
    assert sorted(again) == list(range(1, n))
    alloc.free(again)
    with pytest.raises(ValueError):
        alloc.free([again[0]])                      # double free
    with pytest.raises(ValueError):
        alloc.free([n + 5])                         # out of range
    with pytest.raises(ValueError):
        alloc.free([0])                             # scratch page
    probe = alloc.alloc(2)
    with pytest.raises(ValueError):
        alloc.free([probe[0], probe[0]])            # intra-batch duplicate
    assert alloc.n_free == alloc.capacity - 2       # failed frees change nothing


def test_block_table_array_rejects_truncation():
    """A block table narrower than a sequence's page list must raise —
    silently dropping live pages from the kernel's walk would corrupt
    generation with no visible failure."""
    from repro.serve.engine.pages import PagedKVCache

    kv = PagedKVCache({}, n_pages=16, page_size=4)
    kv.open(0)
    kv.ensure(0, 11)                                # 3 pages
    with pytest.raises(ValueError):
        kv.block_table_array([0], 2)
    bt = kv.block_table_array([0, None], 4)         # padding is fine
    assert bt.shape == (2, 4)
    assert int(bt[0, 3]) == 0 and int(bt[1, 0]) == 0


def test_allocator_rejects_double_free_and_oversize():
    alloc = PageAllocator(5)
    pages = alloc.alloc(3)
    alloc.free(pages)
    with pytest.raises(ValueError):
        alloc.free([pages[0], pages[0]])
    with pytest.raises(MemoryError):
        alloc.alloc(10)
    assert pages_for(17, 8) == 3


def test_oversized_request_rejected(stack):
    adapter = _adapter(stack, "bf16")
    eng = ServeEngine(adapter, n_pages=3, page_size=4)
    with pytest.raises(ValueError):
        eng.submit(EngineRequest(rid=0, prompt=list(range(32)),
                                 sampling=SamplingParams(max_new=8)))
    with pytest.raises(ValueError):
        eng.submit(EngineRequest(rid=1, prompt=[1, 2],
                                 sampling=SamplingParams(max_new=0)))
    stale = EngineRequest(rid=2, prompt=[1, 2])
    stale.n_cached = 3
    with pytest.raises(ValueError):
        eng.submit(stale)


# ----------------------------------------------------------------------
# generalized state model: ssm / hybrid / moe families through the same
# scheduler (kv pages, register slots, or both, per the adapter's spec)
# ----------------------------------------------------------------------

FAMILY_ARCHS = ["mamba2-1.3b", "zamba2-1.2b", "deepseek-moe-16b"]


@pytest.fixture(scope="module")
def family_stack():
    """One (cfg, model, params, adapter) per non-dense family. MoE runs
    through the dense oracle (per-token exact → chunking-invariant): the
    capacity-bounded dispatch's drops depend on chunk length, so gather
    dispatch cannot satisfy a chunked≡whole-prompt parity contract."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            kw = {"moe_dense_oracle": True} if cfg.uses_moe else {}
            model = build_model(cfg, **kw)
            params = model.init(jax.random.PRNGKey(1))
            cache[arch] = (cfg, model, params,
                           as_servable(model, params, cache_dtype=jnp.float32))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_paged_engine_matches_dense_path_families(family_stack, arch):
    """Acceptance: the paged engine serves ssm (register slots only),
    hybrid (kv pages + register slots), and moe (kv pages + routed FFN)
    smoke configs with the same greedy tokens and logits as the
    dense-cache path."""
    cfg, model, params, adapter = family_stack(arch)
    spec = adapter.state_spec
    assert spec.kv == (cfg.family != "ssm")
    assert spec.register == (cfg.family in ("ssm", "hybrid"))
    _, done = _engine_run(adapter, PROMPTS, n_pages=65)
    for rid, prompt in enumerate(PROMPTS):
        want_toks, want_logits = _dense_greedy(adapter, prompt, MAX_NEW)
        req = done[rid]
        assert req.generated == want_toks, (rid, req.generated, want_toks)
        for got, want in zip(req.step_logits, want_logits):
            assert np.corrcoef(got, want)[0, 1] >= 0.999


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_chunked_prefill_matches_stepwise_families(family_stack, arch):
    """Chunked prefill ≡ one-token-at-a-time prefill for the new
    families: carried SSM state across padded chunk boundaries must be
    exact (valid_len masking), not just close."""
    _, _, _, adapter = family_stack(arch)
    _, chunked = _engine_run(adapter, PROMPTS, n_pages=65, prefill_chunk=4)
    _, stepwise = _engine_run(adapter, PROMPTS, n_pages=65, prefill_chunk=1)
    for rid in range(len(PROMPTS)):
        assert chunked[rid].generated == stepwise[rid].generated
        for a, b in zip(chunked[rid].step_logits, stepwise[rid].step_logits):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-1.2b"])
def test_register_slot_leak_accounting(family_stack, arch):
    """admit → finish → readmit: every register slot returns to the free
    list, a recycled slot is reused for the next admission, and scrubbing
    on release means it cannot observe its predecessor's state (all
    non-scratch slot rows are zero between runs — satellite bugfix)."""
    _, _, _, adapter = family_stack(arch)
    eng, _ = _engine_run(adapter, PROMPTS, n_pages=65, max_seqs=2)
    regs = eng.kv.registers
    assert regs is not None
    assert regs.n_free == regs.capacity == 2
    assert not eng.kv.slots
    # scrub-on-release: every allocatable slot (and, for hybrid, every
    # freed kv page) holds zeros — a recycled slot/page cannot leak
    for leaf in jax.tree.leaves(eng.kv.state["register"]):
        assert bool(jnp.all(leaf[:, 1:] == 0)), "stale register state"
    for leaf in jax.tree.leaves(eng.kv.state["kv"]):
        assert bool(jnp.all(leaf[:, 1:] == 0)), "stale kv pages"

    # readmission reuses the freed slot and sees zeroed state
    used_before = set(range(1, regs.n_slots)) - set(regs._free)
    assert not used_before
    eng.submit(EngineRequest(rid=99, prompt=[5, 6, 7],
                             sampling=SamplingParams(max_new=2)))
    eng.step()
    assert eng.kv.slots[99] in range(1, regs.n_slots)
    while eng.queue or eng.active:
        eng.step()
    assert regs.n_free == regs.capacity


def test_moe_capacity_path_serves_end_to_end():
    """The real capacity-bounded gather dispatch (no oracle) must serve
    through the engine too — no parity contract (drops are
    chunk-length-dependent by design), but generation completes with
    finite logits and clean page accounting."""
    cfg = get_config("deepseek-moe-16b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    adapter = as_servable(model, params)
    eng, done = _engine_run(adapter, PROMPTS, n_pages=65)
    for rid in range(len(PROMPTS)):
        assert len(done[rid].generated) == MAX_NEW
        assert all(np.isfinite(lg).all() for lg in done[rid].step_logits)
    assert eng.kv.allocator.n_free == eng.kv.allocator.capacity


@pytest.mark.parametrize("arch,match", [
    ("hubert-xlarge", "encoder"),        # no autoregressive decode
    ("internvl2-2b", "frontend"),        # non-token inputs
])
def test_adapter_rejects_unservable_families(arch, match):
    """Capability check regression: genuinely unservable configs fail at
    adapter construction with a clear error, not deep inside the engine."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match=match):
        as_servable(model, params)


def test_engine_respects_use_kernels_scope(stack):
    """The fused phase jits must compile once per kernels-enabled state
    (like `QuantizedDenseLM._jitted`), so dispatched-vs-reference
    comparisons through the engine are real — and bit-identical, since
    both paths compute the same arithmetic."""
    from repro.kernels import ops as kops

    adapter = _adapter(stack, "int_kv8")
    runs = {}
    for enabled in (True, False):
        with kops.use_kernels(enabled):
            _, done = _engine_run(adapter, PROMPTS[:1])
        runs[enabled] = done[0]
    assert runs[True].generated == runs[False].generated
    for a, b in zip(runs[True].step_logits, runs[False].step_logits):
        np.testing.assert_array_equal(a, b)


# ----------------------------------------------------------------------
# robustness satellites: admission bookkeeping + submit-time validation
# ----------------------------------------------------------------------

@pytest.mark.parametrize("admission", ["reserve", "optimistic"])
def test_committed_total_matches_sum(stack, admission):
    """The O(n) admission bookkeeping: the running `_committed_total`
    equals `sum(_committed.values())` after every step under both
    policies (admit, growth, finish all update it), and drains to zero."""
    adapter = _adapter(stack, "bf16")
    prompts = [[(7 * i + j) % 500 for j in range(3 + i % 4)]
               for i in range(8)]
    eng = ServeEngine(adapter, n_pages=5, page_size=8, max_seqs=2,
                      prefill_chunk=4, admission=admission)
    for rid, p in enumerate(prompts):
        eng.submit(EngineRequest(rid=rid, prompt=list(p),
                                 sampling=SamplingParams(max_new=3)))
    done = []
    while eng.queue or eng.active:
        done.extend(eng.step())
        assert eng._committed_total == sum(eng._committed.values())
        eng.check_books()
    assert len(done) == len(prompts)
    assert eng._committed_total == 0 and not eng._committed
    assert eng.kv.allocator.n_free == eng.kv.allocator.capacity


def test_both_admission_policies_same_tokens(stack):
    """With an ample pool, optimistic and reserve admission produce
    bit-identical generations — the policy only changes *when* requests
    are admitted, never what they generate."""
    adapter = _adapter(stack, "bf16")
    runs = {}
    for mode in ("reserve", "optimistic"):
        _, done = _engine_run(adapter, PROMPTS, admission=mode)
        runs[mode] = {r: done[r].generated for r in done}
    assert runs["reserve"] == runs["optimistic"]


def test_submit_rejects_over_context_window(stack, family_stack):
    """Satellite: prompt + max_new beyond the model context window is
    rejected at submit with a clear error — for kv specs (where the pool
    implies a bound) AND register-only specs (which reserve 0 pages and
    previously sailed through to fail deep inside prefill)."""
    adapter = _adapter(stack, "bf16")
    # kv spec, explicit window
    eng = ServeEngine(adapter, n_pages=33, page_size=8, max_context=16)
    with pytest.raises(ValueError, match="context window"):
        eng.submit(EngineRequest(rid=0, prompt=list(range(12)),
                                 sampling=SamplingParams(max_new=8)))
    # kv spec, implied window = capacity · page_size (32 · 8 = 256)
    assert eng.max_context == 16
    eng2 = ServeEngine(adapter, n_pages=33, page_size=8)
    assert eng2.max_context == 32 * 8

    # register-only spec: no pool-implied bound, but an explicit window
    # must still reject at submit
    _, _, _, ssm_adapter = family_stack("mamba2-1.3b")
    assert not ssm_adapter.state_spec.kv
    eng3 = ServeEngine(ssm_adapter, n_pages=5, page_size=8, max_context=10)
    with pytest.raises(ValueError, match="context window"):
        eng3.submit(EngineRequest(rid=0, prompt=list(range(8)),
                                  sampling=SamplingParams(max_new=8)))
    eng4 = ServeEngine(ssm_adapter, n_pages=5, page_size=8)
    assert eng4.max_context is None     # register state never grows


def test_optimistic_submit_rejects_never_admittable(stack):
    """A prompt whose pages can never fit beside the headroom watermark
    is rejected at submit instead of stalling the queue forever."""
    adapter = _adapter(stack, "bf16")
    eng = ServeEngine(adapter, n_pages=5, page_size=4, max_seqs=2,
                      admission="optimistic", headroom_pages=2)
    with pytest.raises(ValueError, match="never be admitted"):
        eng.submit(EngineRequest(rid=0, prompt=list(range(12)),
                                 sampling=SamplingParams(max_new=2)))


def test_on_token_streams_at_step_boundaries(stack):
    """`submit(req, on_token=...)` delivers every generated token exactly
    once, in order, at the boundary of the step that produced it — and a
    request without a callback costs nothing."""
    from repro.serve.engine import ServeEngine as _SE
    adapter = _adapter(stack, "bf16")
    eng = _SE(adapter, n_pages=33, page_size=8, max_seqs=2,
              prefill_chunk=4)
    streamed: dict[int, list[int]] = {0: [], 2: []}
    for rid, p in enumerate(PROMPTS):
        cb = (lambda r, t: streamed[r].append(t)) if rid in streamed \
            else None
        eng.submit(EngineRequest(rid=rid, prompt=list(p),
                                 sampling=SamplingParams(max_new=MAX_NEW)),
                   on_token=cb)
    done = {}
    while eng.queue or eng.active:
        for r in eng.step():
            done[r.rid] = r
        # boundary contract: after each step, everything generated so
        # far has been delivered — no buffering across steps
        for req in eng.active:
            if req.rid in streamed:
                assert streamed[req.rid] == req.generated
    for rid in streamed:
        assert streamed[rid] == done[rid].generated
        assert len(streamed[rid]) == MAX_NEW


class _PoisonAdapter:
    """Delegating adapter that returns NaN logits for any row whose true
    context length equals `poison_len` — a deterministic stand-in for
    numerically-poisoned model output (overflowed activation, corrupted
    weight). Everything else passes straight through to the inner
    adapter, so other rows of the same fused dispatch are untouched."""

    def __init__(self, inner, poison_len):
        self._inner = inner
        self._poison_len = poison_len

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def forward_chunk(self, params, tokens, state, pos, bt=None, lens=None,
                      reg=None, **kw):
        out = self._inner.forward_chunk(params, tokens, state, pos, bt,
                                        lens, reg, **kw)
        logits = jnp.where((lens == self._poison_len)[:, None, None],
                           jnp.nan, out[0])
        return (logits,) + tuple(out[1:])


def test_poisoned_row_fails_without_perturbing_batch(stack):
    """Satellite: a non-finite logits row terminates exactly that request
    (`outcome="failed"`, counted `engine.requests.poisoned`) instead of
    entering a garbage token into its stream or crashing the step; the
    other rows of the same fused dispatches stay bit-identical."""
    adapter = _adapter(stack, "bf16")
    _, base = _engine_run(adapter, PROMPTS, max_new=5)
    # decode lens = n_cached + 1, so rid 2 (prompt 7) spans 8..11 while
    # rid 0 (prompt 5) tops out at 9 and rid 1 (prompt 3) at 7 — length
    # 10 poisons exactly rid 2's 4th-generated-token dispatch, mid-decode
    eng, done = _engine_run(_PoisonAdapter(adapter, poison_len=10),
                            PROMPTS, max_new=5)
    assert done[2].outcome == "failed"
    assert "non-finite logits" in done[2].failed
    # tokens generated before the poison are the baseline's, and the
    # poisoned sample itself never entered the stream
    assert done[2].generated == base[2].generated[:3]
    assert eng.metrics.counter("engine.requests.poisoned").value == 1
    assert eng.metrics.counter("engine.requests.failed").value == 1
    for rid in (0, 1):
        assert done[rid].outcome == "length"
        assert done[rid].generated == base[rid].generated, rid


def test_drain_finishes_inflight_rejects_new(stack):
    """Satellite: drain() stops admission (never-admitted queue entries
    cancel), finishes all in-flight work, asserts every pool empty, and
    rejects subsequent submits."""
    adapter = _adapter(stack, "bf16")
    _, base = _engine_run(adapter, PROMPTS)
    eng = ServeEngine(adapter, n_pages=33, page_size=8, max_seqs=2,
                      prefill_chunk=4)
    for rid, p in enumerate(PROMPTS):
        eng.submit(EngineRequest(rid=rid, prompt=list(p),
                                 sampling=SamplingParams(max_new=MAX_NEW)))
    done = eng.step()                 # rid 0/1 admitted; rid 2 queued
    done += eng.drain()
    by_rid = {r.rid: r for r in done}
    assert len(by_rid) == len(PROMPTS)
    assert by_rid[2].outcome == "cancelled" and not by_rid[2].generated
    for rid in (0, 1):
        assert by_rid[rid].outcome == "length"
        assert by_rid[rid].generated == base[rid].generated
    with pytest.raises(RuntimeError, match="draining"):
        eng.submit(EngineRequest(rid=9, prompt=[1],
                                 sampling=SamplingParams(max_new=1)))


def test_stream_callback_error_isolated(stack):
    """Satellite: a raising on_token callback is counted and dropped —
    it cannot abort the step or starve the other streams, which still
    deliver every token exactly once."""
    adapter = _adapter(stack, "bf16")
    eng = ServeEngine(adapter, n_pages=33, page_size=8, max_seqs=2,
                      prefill_chunk=4)
    got: list[int] = []

    def bad(rid, tok):
        raise RuntimeError("consumer died")

    for rid, p in enumerate(PROMPTS):
        cb = {0: bad, 1: lambda r, t: got.append(t)}.get(rid)
        eng.submit(EngineRequest(rid=rid, prompt=list(p),
                                 sampling=SamplingParams(max_new=MAX_NEW)),
                   on_token=cb)
    done = {r.rid: r for r in eng.run()}
    assert len(done) == len(PROMPTS)
    assert all(r.outcome == "length" for r in done.values())
    # the broken consumer was dropped after its first raise
    assert eng.metrics.counter("engine.stream.callback_errors").value == 1
    assert 0 not in eng._callbacks
    # the healthy stream delivered everything exactly once, in order
    assert got == done[1].generated


def test_release_scrubs_in_one_fused_dispatch(stack):
    """Satellite: each request release batches its scrub into exactly ONE
    fused dispatch (tallied as `scrub_state` in the kernels.ops counts),
    regardless of how many pages it frees."""
    from repro.kernels import ops as kops

    def scrubs():
        return sum(v for (entry, _), v in kops.dispatch_counts().items()
                   if entry == "scrub_state")

    adapter = _adapter(stack, "bf16")
    eng, done = _engine_run(adapter, PROMPTS)
    assert len(done) == len(PROMPTS)
    kops.reset_dispatch_counts()
    eng2, _ = _engine_run(adapter, PROMPTS)
    # fault-free run, no sharing: one release — one scrub — per request
    assert scrubs() == len(PROMPTS)
    assert eng2.kv.pages_scrubbed >= len(PROMPTS)
