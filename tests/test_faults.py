"""Chaos tests: the engine's robustness invariants under injected faults.

Every test drives the paged engine through a deterministic `FaultPlan`
(or a genuinely undersized page pool) and asserts the invariants the
preemption/lifecycle machinery promises:

  * no page or register-slot leaks after any interleaving — the
    allocator free list covers capacity again once every request reaches
    a terminal state;
  * the allocator and `_committed` books balance after every single
    step (`ServeEngine.check_books`), not just at the end;
  * survivors are bit-identical to an undisturbed run — preemption,
    replays, cancels, expiries, and dispatch faults of *other* requests
    never perturb a request's own tokens, because sampling keys derive
    from `(rid, position)` and the paged forward is row-independent;
  * a preempted-and-replayed request reproduces exactly the
    continuation it would have produced without the preemption.

The fault-free baseline and the faulted runs share identical engine
geometry (same `max_seqs`/`page_size`/`prefill_chunk`/`n_pages`) so
every dispatch has identical shapes and token comparisons can demand
bit-identity rather than tolerance.
"""
import os

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.transformer import build_model
from repro.serve.engine import (DispatchFault, EngineRequest,
                                EngineStalledError, FaultPlan,
                                SamplingParams, ServeEngine, as_servable)

pytestmark = pytest.mark.chaos

MAX_NEW = 5
PROMPTS = [[3, 14, 15, 92, 6], [53, 58, 9], [7, 9, 3, 23, 84, 62, 43],
           [41, 5, 27, 18, 2, 88, 31, 7, 64]]
GEOM = dict(n_pages=33, page_size=4, max_seqs=2, prefill_chunk=4)


@pytest.fixture(scope="module")
def adapter():
    cfg = get_config("llama3-1b").reduced()
    model = build_model(cfg)
    return as_servable(model, model.init(jax.random.PRNGKey(0)))


def _submit_all(eng, *, temperature=0.0):
    for rid, p in enumerate(PROMPTS):
        eng.submit(EngineRequest(
            rid=rid, prompt=list(p),
            sampling=SamplingParams(temperature=temperature,
                                    max_new=MAX_NEW)))


def _run_checked(eng):
    """run() with the book-balance invariant asserted after every step."""
    done = []
    while eng.queue or eng.active:
        done.extend(eng.step())
        eng.check_books()
    return {r.rid: r for r in done}


def _assert_drained(eng):
    """Terminal quiescence: no leaks of pages, slots, or bookkeeping."""
    alloc = eng.kv.allocator
    assert alloc.in_use == 0 and alloc.n_free == alloc.capacity
    assert not eng.kv.tables and not eng.kv.slots
    assert not eng._committed and eng._committed_total == 0
    eng.check_books()


@pytest.fixture(scope="module")
def baseline(adapter):
    """Fault-free greedy run in the shared geometry: rid → tokens."""
    eng = ServeEngine(adapter, **GEOM)
    _submit_all(eng)
    done = _run_checked(eng)
    _assert_drained(eng)
    assert all(done[r].outcome == "length" for r in done)
    return {r: done[r].generated for r in done}


def _counter(eng, name):
    return eng.metrics.counter(name).value


def test_genuine_exhaustion_preempts_and_replays(adapter, baseline):
    """A pool genuinely too small for the concurrent demand forces real
    preemption; every request still completes, and each preempted-and-
    replayed request reproduces its original greedy continuation."""
    eng = ServeEngine(adapter, n_pages=5, page_size=4, max_seqs=2,
                      prefill_chunk=4, max_preemptions=10)
    _submit_all(eng)
    done = _run_checked(eng)
    _assert_drained(eng)
    assert _counter(eng, "engine.preemptions") >= 1
    assert _counter(eng, "engine.replayed_prefill_tokens") > 0
    assert len(done) == len(PROMPTS)
    for rid, toks in baseline.items():
        assert done[rid].outcome == "length"
        assert done[rid].generated == toks, rid


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_injected_exhaustion_bit_identical(adapter, baseline, temperature):
    """Injected exhaustion (ample pool, identical geometry) drives the
    preemption path; survivors — including the preempted request itself —
    are bit-identical to the undisturbed run, for greedy AND sampled
    decoding (the (rid, position) key contract)."""
    if temperature > 0:
        base_eng = ServeEngine(adapter, **GEOM)
        _submit_all(base_eng, temperature=temperature)
        base = {r: req.generated
                for r, req in _run_checked(base_eng).items()}
    else:
        base = baseline
    eng = ServeEngine(adapter, **GEOM,
                      faults=FaultPlan(exhaust_steps=(2, 5)))
    _submit_all(eng, temperature=temperature)
    done = _run_checked(eng)
    _assert_drained(eng)
    assert _counter(eng, "engine.preemptions") >= 1
    for rid, toks in base.items():
        assert done[rid].generated == toks, rid


def test_cancel_queued_and_midflight(adapter, baseline):
    """cancel(rid) takes a request out of any phase with its pages
    scrubbed and accounted; the others are undisturbed."""
    eng = ServeEngine(adapter, **GEOM)
    _submit_all(eng)
    # rid 3 is still queued (max_seqs=2); cancel it before any step
    q = eng.cancel(3)
    assert q.cancelled and q.outcome == "cancelled"
    eng.step()
    # by now rid 0/1 are mid-flight; cancel one of them
    m = eng.cancel(0)
    assert m.cancelled and 0 not in eng.kv.tables
    eng.check_books()
    done = _run_checked(eng)
    done.update({0: m, 3: q})
    _assert_drained(eng)
    assert _counter(eng, "engine.requests.cancelled") == 2
    for rid in (1, 2):
        assert done[rid].generated == baseline[rid], rid
    assert eng.metrics.counter("engine.requests.finished").value == 2
    with pytest.raises(ValueError, match="not queued or active"):
        eng.cancel(0)


def test_deadline_expiry(adapter, baseline):
    """An elapsed deadline_s expires the request at the next step
    boundary, queued or mid-flight, returning its pages."""
    eng = ServeEngine(adapter, **GEOM)
    for rid, p in enumerate(PROMPTS):
        eng.submit(EngineRequest(
            rid=rid, prompt=list(p), deadline_s=None if rid != 2 else -1.0,
            sampling=SamplingParams(max_new=MAX_NEW)))
    done = _run_checked(eng)
    _assert_drained(eng)
    assert done[2].expired and done[2].outcome == "expired"
    assert not done[2].generated
    assert _counter(eng, "engine.requests.expired") == 1
    for rid in (0, 1, 3):
        assert done[rid].generated == baseline[rid], rid


def test_engine_default_deadline_applies(adapter):
    """An engine-level deadline_s is inherited by requests that don't
    set their own; everything expires, nothing leaks."""
    eng = ServeEngine(adapter, **GEOM, deadline_s=-1.0)
    _submit_all(eng)
    done = _run_checked(eng)
    _assert_drained(eng)
    assert all(done[r].expired for r in done)
    assert _counter(eng, "engine.requests.expired") == len(PROMPTS)


def test_dispatch_faults_do_not_perturb(adapter, baseline):
    """Injected dispatch failures/delays cost steps, never tokens."""
    eng = ServeEngine(adapter, **GEOM,
                      faults=FaultPlan(dispatch_fail_steps=(1, 4),
                                       dispatch_delay_steps=(2,),
                                       dispatch_delay_s=0.001))
    _submit_all(eng)
    done = _run_checked(eng)
    _assert_drained(eng)
    assert _counter(eng, "engine.dispatch.faults") == 3
    for rid, toks in baseline.items():
        assert done[rid].generated == toks, rid


def test_random_chaos_interleavings(adapter, baseline):
    """Seeded random chaos — exhaustions, cancels, expiries, dispatch
    failures all at once: after any interleaving the books balance every
    step, nothing leaks, every submitted request reaches exactly one
    terminal state, and survivors stay bit-identical."""
    # FAULT_SEED offsets the seed window: the CI chaos matrix sweeps it
    # so each leg explores different interleavings of the same plan shape
    base_seed = int(os.environ.get("FAULT_SEED", "0"))
    for seed in range(base_seed * 5, base_seed * 5 + 5):
        plan = FaultPlan(seed=seed, exhaust_rate=0.3, cancel_rate=0.25,
                         expire_rate=0.15, dispatch_fail_rate=0.1)
        eng = ServeEngine(adapter, **GEOM, max_preemptions=10, faults=plan)
        _submit_all(eng)
        done = _run_checked(eng)
        _assert_drained(eng)
        assert len(done) == len(PROMPTS)
        outcomes = {rid: done[rid].outcome for rid in done}
        assert all(o in ("length", "cancelled", "expired", "failed")
                   for o in outcomes.values()), outcomes
        c = eng.metrics
        assert (c.counter("engine.requests.finished").value
                + c.counter("engine.requests.cancelled").value
                + c.counter("engine.requests.expired").value
                + c.counter("engine.requests.failed").value) == len(PROMPTS)
        for rid, req in done.items():
            if req.outcome == "length":
                assert req.generated == baseline[rid], (seed, rid)


def test_identical_plans_replay_identical_faults(adapter):
    """The FaultPlan determinism contract: same seed, same trace → the
    same faults fire and the run is step-for-step identical."""
    runs = []
    for _ in range(2):
        plan = FaultPlan(seed=3, exhaust_rate=0.4, cancel_rate=0.2)
        eng = ServeEngine(adapter, **GEOM, max_preemptions=10, faults=plan)
        _submit_all(eng)
        done = _run_checked(eng)
        runs.append({
            "outcomes": {r: done[r].outcome for r in done},
            "tokens": {r: done[r].generated for r in done},
            "preempt": _counter(eng, "engine.preemptions"),
            "cancel": _counter(eng, "engine.requests.cancelled"),
            "steps": eng.n_steps,
        })
    assert runs[0] == runs[1]


def test_preemption_limit_fails_terminally(adapter):
    """max_preemptions bounds the replay loop: a request preempted past
    the limit fails with a diagnosable reason instead of livelocking."""
    # step 3 is the first decode step where a sequence actually crosses
    # a page boundary, so the injection coincides with a growth attempt
    eng = ServeEngine(adapter, **GEOM, max_preemptions=0,
                      faults=FaultPlan(exhaust_steps=(3,)))
    _submit_all(eng)
    done = _run_checked(eng)
    _assert_drained(eng)
    failed = [r for r in done.values() if r.failed is not None]
    assert len(failed) == 1
    assert "preempted" in failed[0].failed
    assert failed[0].outcome == "failed"
    assert _counter(eng, "engine.requests.failed") == 1


def test_stall_detector_diagnoses(adapter):
    """A head-of-line demand that can never be satisfied raises a
    diagnosable EngineStalledError (who is blocked, on how many pages)
    instead of spinning. submit() rejects such requests up front, so the
    stall is staged by planting an oversized request on the queue."""
    eng = ServeEngine(adapter, n_pages=5, page_size=4, max_seqs=2)
    big = EngineRequest(rid=9, prompt=list(range(40)),
                        sampling=SamplingParams(max_new=4))
    eng.queue.append(big)    # bypasses submit's capacity validation
    with pytest.raises(EngineStalledError, match=r"rid 9 needs \d+ pages"):
        eng.step()


def test_faultplan_validation():
    with pytest.raises(ValueError, match="cancel_rate"):
        FaultPlan(cancel_rate=1.5)
    with pytest.raises(ValueError, match="swap_fail_rate"):
        FaultPlan(swap_fail_rate=-0.1)
    with pytest.raises(ValueError, match="dispatch_delay_s"):
        FaultPlan(dispatch_delay_s=-0.5)
    with pytest.raises(ValueError, match="exhaust_steps.*negative"):
        FaultPlan(exhaust_steps=(2, -1))
    with pytest.raises(ValueError, match="swap_fail_steps.*negative"):
        FaultPlan(swap_fail_steps=(-3,))
    with pytest.raises(ValueError, match="cancel_at.*negative"):
        FaultPlan(cancel_at={-2: (0,)})
    with pytest.raises(ValueError, match="expire_at.*negative"):
        FaultPlan(expire_at={-1: (1,)})
    plan = FaultPlan(exhaust_steps=(3,))
    assert plan.take_exhaustion(3) is True
    assert plan.take_exhaustion(3) is False     # at most once per step
    assert plan.take_exhaustion(4) is False
    assert plan.take_dispatch_fault(0) is None
    assert isinstance(DispatchFault("x"), RuntimeError)


def test_swap_fault_latch_shared_across_directions():
    """take_swap_fault fires at most once per step, shared across
    swap-out/swap-in: whichever direction asks first that step takes the
    fault, the retry within the step sees a healthy tier."""
    from repro.serve.engine import SwapFault

    plan = FaultPlan(swap_fail_steps=(2,))
    assert plan.take_swap_fault(1) is False
    assert plan.take_swap_fault(2) is True
    assert plan.take_swap_fault(2) is False     # latched for the step
    assert plan.take_swap_fault(3) is False
    assert isinstance(SwapFault("x"), RuntimeError)
    # rate-driven faults are deterministic in (seed, step)
    a = [FaultPlan(seed=7, swap_fail_rate=0.5).take_swap_fault(s)
         for s in range(20)]
    b = [FaultPlan(seed=7, swap_fail_rate=0.5).take_swap_fault(s)
         for s in range(20)]
    assert a == b and any(a) and not all(a)
