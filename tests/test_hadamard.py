"""Hadamard construction + transform tests, including paper Tables 3/4."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hadamard as hd

SMALL_ORDERS = [1, 2, 4, 8, 12, 16, 20, 28, 32, 36, 44, 64, 76, 128, 256, 300]
ASSIGNED_DIMS = [504, 1024, 1280, 1408, 2048, 2816, 3072, 5120, 6144, 7168,
                 8192, 9728, 12288, 14336, 19200]


@pytest.mark.parametrize("n", SMALL_ORDERS)
def test_construction_is_hadamard(n):
    H = hd.hadamard(n)
    if n >= 4:
        assert hd.is_hadamard(H)
    assert H.shape == (n, n)
    assert set(np.unique(H)) <= {-1, 1}


@pytest.mark.parametrize("d", ASSIGNED_DIMS)
def test_assigned_dims_constructible(d):
    assert hd.constructible(d), f"no Hadamard construction for assigned dim {d}"


def test_nonconstructible_raises():
    with pytest.raises(ValueError):
        hd.hadamard(6)  # n % 4 != 0


@pytest.mark.parametrize("d", [2, 8, 64, 512])
def test_fwht_matches_sylvester(d):
    x = jax.random.normal(jax.random.PRNGKey(0), (5, d))
    H = jnp.asarray(hd.sylvester(d).astype(np.float32)) / math.sqrt(d)
    np.testing.assert_allclose(np.asarray(hd.fwht(x)), np.asarray(x @ H),
                               atol=1e-4)


@pytest.mark.parametrize("d", [12, 24, 28, 56, 96, 112, 1280])
def test_nonpow2_transform_matches_dense(d):
    x = jax.random.normal(jax.random.PRNGKey(1), (3, d))
    H = jnp.asarray(hd.hadamard(d).astype(np.float32)) / math.sqrt(d)
    np.testing.assert_allclose(np.asarray(hd.hadamard_transform(x)),
                               np.asarray(x @ H), atol=1e-4)


@pytest.mark.parametrize("d,b", [(64, 16), (96, 12), (256, 32), (512, 128)])
def test_block_transform_matches_kron(d, b):
    x = jax.random.normal(jax.random.PRNGKey(2), (4, d))
    got = np.asarray(hd.block_hadamard_transform(x, b))
    want = np.asarray(x @ hd.block_hadamard_matrix(d, b))
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_transform_is_orthonormal():
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 224))
    y = hd.hadamard_transform(x)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)


# ---- paper Tables 3 & 4 (exact numbers) -----------------------------------

TABLE3 = [  # (d, b→ops for 32/128/512, full)
    (8192, {32: 40960, 128: 57344, 512: 73728}, 106496),
    (14336, {32: 71680, 128: 100352, 512: 129024}, 258048),
    (6144, {32: 30720, 128: 43008, 512: 55296}, 86016),
    (9728, {32: 48640, 128: 68096, 512: 87552}, 272384),
    (12288, {32: 61440, 128: 86016, 512: 110592}, 184320),
]


@pytest.mark.parametrize("d,blocks,full", TABLE3)
def test_table3_op_counts(d, blocks, full):
    for b, want in blocks.items():
        assert hd.ops_block(d, b) == want
    assert hd.ops_full_vector(d) == full


TABLE4 = [  # (d, matmul, butterfly+matmul, ours)
    (14336, 205_520_896, 516_096, 258_048),
    (3072, 9_437_184, 58_368, 39_936),
    (6144, 37_748_736, 122_880, 86_016),
    (9728, 94_633_984, 797_696, 272_384),
    (12288, 150_994_944, 258_048, 184_320),
]


@pytest.mark.parametrize("d,mm,bfly,ours", TABLE4)
def test_table4_op_counts(d, mm, bfly, ours):
    assert hd.ops_dense_matmul(d) == mm
    assert hd.ops_butterfly_matmul(d) == bfly
    assert hd.ops_optimized(d) == ours


def test_random_orthogonal_fallback():
    q = hd.random_orthogonal(10, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(q @ q.T), np.eye(10), atol=1e-5)
