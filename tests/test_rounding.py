"""GPTQ / Qronos rounding tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantizers as qz
from repro.core import rounding as rd


def _setup(d_in=64, d_out=48, n_tok=512, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    # anisotropic activations (what makes GPTQ matter)
    x = jax.random.normal(k1, (n_tok, d_in)) * (1 + jnp.arange(d_in) * 0.05)
    w = jax.random.normal(k2, (d_in, d_out)) * 0.3
    return x, w


@pytest.mark.parametrize("fmt", ["int4", "fp4", "mxfp4"])
def test_gptq_beats_rtn_on_layer_output(fmt):
    x, w = _setup()
    h = rd.hessian_from_activations(x)
    spec = qz.QuantSpec(fmt=fmt)
    e_rtn = jnp.linalg.norm(x @ rd.rtn(w, spec) - x @ w)
    e_gptq = jnp.linalg.norm(x @ rd.gptq(w, h, spec) - x @ w)
    assert float(e_gptq) < float(e_rtn)


def test_gptq_weights_live_on_quant_grid():
    x, w = _setup()
    h = rd.hessian_from_activations(x)
    spec = qz.QuantSpec(fmt="int4")
    wq = rd.gptq(w, h, spec)
    # re-quantizing with the same scales must be a fixed point
    s = rd.row_scales(wq, spec)
    wq2 = qz.int_quantize(wq, s, 0.0, 4)
    np.testing.assert_allclose(np.asarray(wq2), np.asarray(wq), atol=2e-5)


def test_qronos_reduces_to_gptq_without_cross_term():
    x, w = _setup()
    h = rd.hessian_from_activations(x)
    spec = qz.QuantSpec(fmt="int4")
    wq1 = rd.qronos(w, h, spec, c_qx=None)
    wq2 = rd.gptq(w, h, spec, damp_sigma=1e-3)
    np.testing.assert_allclose(np.asarray(wq1), np.asarray(wq2), atol=1e-6)


def test_qronos_beats_gptq_with_quantized_inputs():
    x, w = _setup(seed=1)
    xq = qz.quantize_act(x, qz.QuantSpec(fmt="int4"))
    hq = rd.hessian_from_activations(xq)
    c = rd.cross_from_activations(xq, x)
    spec = qz.QuantSpec(fmt="int4")
    target = x @ w  # the full-precision function we want to preserve
    e_gptq = jnp.linalg.norm(xq @ rd.gptq(w, hq, spec) - target)
    e_qron = jnp.linalg.norm(xq @ rd.qronos(w, hq, spec, c_qx=c) - target)
    assert float(e_qron) < float(e_gptq)


def test_gptq_handles_dead_channels():
    x, w = _setup()
    x = x.at[:, 7].set(0.0)  # dead input channel
    h = rd.hessian_from_activations(x)
    wq = rd.gptq(w, h, qz.QuantSpec(fmt="int4"))
    assert bool(jnp.all(jnp.isfinite(wq)))


def test_gptq_act_order_matches_identity_on_isotropic_h():
    """With H = I the error diffusion is a no-op: GPTQ == RTN exactly."""
    _, w = _setup()
    h = jnp.eye(w.shape[0]) * 100.0
    spec = qz.QuantSpec(fmt="int4")
    wq_gptq = rd.gptq(w, h, spec, act_order=False)
    wq_rtn = rd.rtn(w, spec)
    np.testing.assert_allclose(np.asarray(wq_gptq), np.asarray(wq_rtn),
                               atol=2e-5)
