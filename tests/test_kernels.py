"""Per-kernel interpret-mode validation: shape/dtype sweeps vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as kref
from repro.kernels.block_hadamard import block_hadamard
from repro.kernels.hadamard_quant import hadamard_quant
from repro.kernels.int4_matmul import int4_matmul

KEY = jax.random.PRNGKey(0)


# -------------------- block_hadamard --------------------

@pytest.mark.parametrize("m,d,b", [
    (4, 64, 16), (32, 128, 32), (7, 256, 128), (100, 512, 512),
    (16, 384, 96),  # non-pow2 block (Hadamard-12 base)
    (1, 128, 16),   # single row
    (300, 256, 256),  # rows not multiple of tile
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_hadamard_matches_ref(m, d, b, dtype):
    x = (jax.random.normal(KEY, (m, d)) * 4).astype(dtype)
    got = block_hadamard(x, b, interpret=True)
    want = kref.block_hadamard_ref(x, b)
    assert got.shape == want.shape and got.dtype == want.dtype
    atol = 1e-4 if dtype == jnp.float32 else 0.125
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_block_hadamard_3d_batch():
    x = jax.random.normal(KEY, (3, 5, 128))
    got = block_hadamard(x, 32, interpret=True)
    want = kref.block_hadamard_ref(x, 32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_block_hadamard_is_involution_energy():
    """Orthonormality: applying twice to H-symmetric blocks preserves norms."""
    x = jax.random.normal(KEY, (10, 256))
    y = block_hadamard(x, 64, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)


# -------------------- hadamard_quant --------------------

@pytest.mark.parametrize("m,d,b", [(16, 128, 32), (65, 256, 16), (8, 512, 128)])
@pytest.mark.parametrize("bits", [4, 8])
def test_hadamard_quant_matches_ref(m, d, b, bits):
    x = jax.random.normal(KEY, (m, d)) * 3
    gc, gs, gz = hadamard_quant(x, b, bits=bits, interpret=True)
    wc, ws, wz = kref.hadamard_quant_ref(x, b, bits)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gz), np.asarray(wz), atol=1)
    # codes may differ ±1 on rounding ties; compare dequantized values
    deq_g = np.asarray(gs) * (np.asarray(gc, np.float32) + np.asarray(gz))
    deq_w = np.asarray(ws) * (np.asarray(wc, np.float32) + np.asarray(wz))
    np.testing.assert_allclose(deq_g, deq_w, atol=float(np.asarray(ws).max()))


def test_hadamard_quant_dequant_error_bounded():
    x = jax.random.normal(KEY, (32, 256))
    c, s, z = hadamard_quant(x, 32, bits=4, interpret=True)
    deq = np.asarray(s) * (np.asarray(c, np.float32) + np.asarray(z))
    rot = np.asarray(kref.block_hadamard_ref(x, 32))
    # max error ≤ step size (asym 4-bit: range/15)
    step = (rot.max(-1) - rot.min(-1)) / 15
    assert (np.abs(deq - rot).max(-1) <= step + 1e-5).all()


# -------------------- int4 pack / matmul --------------------

def test_pack_unpack_roundtrip():
    codes = jax.random.randint(KEY, (64, 32), -8, 8, dtype=jnp.int8)
    packed = kref.int4_pack(codes)
    assert packed.shape == (32, 32) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(kref.int4_unpack(packed)),
                                  np.asarray(codes))


@pytest.mark.parametrize("m,k,n", [(8, 64, 32), (33, 128, 128), (4, 256, 64)])
def test_int4_matmul_matches_ref(m, k, n):
    k1, k2, k3 = jax.random.split(KEY, 3)
    act_codes = jax.random.randint(k1, (m, k), 0, 16, dtype=jnp.int8)
    act_scale = jax.random.uniform(k2, (m, 1), minval=0.01, maxval=0.2)
    act_zero = jnp.round(jax.random.uniform(k3, (m, 1), minval=-8, maxval=0))
    w_codes = jax.random.randint(k2, (k, n), -8, 8, dtype=jnp.int8)
    w_packed = kref.int4_pack(w_codes)
    w_scale = jax.random.uniform(k1, (n,), minval=0.01, maxval=0.1)
    got = int4_matmul(act_codes, act_scale, act_zero, w_packed, w_scale,
                      tm=16, tn=32, tk=64, interpret=True)
    want = kref.int4_matmul_ref(act_codes, act_scale, act_zero, w_packed,
                                w_scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_int4_matmul_equals_float_path():
    """End-to-end: integer GEMM == dequantize-then-matmul exactly."""
    m, k, n = 16, 128, 64
    x = jax.random.normal(KEY, (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.2
    # quantize
    act_codes, s_a, z_a = kref.quantize_act_int_ref(x, 4)
    s_w = jnp.max(jnp.abs(w), axis=0) / 7
    w_codes = jnp.clip(jnp.round(w / s_w[None]), -7, 7).astype(jnp.int8)
    w_packed = kref.int4_pack(w_codes)
    got = int4_matmul(act_codes, s_a, z_a, w_packed, s_w,
                      tm=16, tn=64, tk=128, interpret=True)
    x_deq = s_a * (act_codes.astype(jnp.float32) + z_a)
    w_deq = w_codes.astype(jnp.float32) * s_w[None]
    want = x_deq @ w_deq
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("m,k,n", [
    (4, 64, 502),    # N = 2·251: largest divisor ≤ 128 is 2 → pad to 512
    (4, 502, 64),    # K = 2·251: only even divisor ≤ 256 is 2 → pad
    (3, 502, 502),   # both awkward at once, M not a tile multiple either
])
def test_int4_matmul_awkward_dims_pad_and_slice(m, k, n):
    """Non-power-of-two projection widths whose only small divisors are
    tiny must not hard-fail (or crawl on 2-wide tiles): the kernel pads
    the awkward dim to the preferred tile with zeros — an exact no-op for
    every real output element — and slices the pad off."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    act_codes = jax.random.randint(k1, (m, k), 0, 16, dtype=jnp.int8)
    act_scale = jax.random.uniform(k2, (m, 1), minval=0.01, maxval=0.2)
    act_zero = jnp.round(jax.random.uniform(k3, (m, 1), minval=-8, maxval=0))
    w_codes = jax.random.randint(k2, (k, n), -8, 8, dtype=jnp.int8)
    w_packed = kref.int4_pack(w_codes)
    w_scale = jax.random.uniform(k1, (n,), minval=0.01, maxval=0.1)
    got = int4_matmul(act_codes, act_scale, act_zero, w_packed, w_scale,
                      interpret=True)
    want = kref.int4_matmul_ref(act_codes, act_scale, act_zero, w_packed,
                                w_scale)
    assert got.shape == (m, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_rope_frequency_literals_agree():
    """`models.layers.rope_frequencies` (traced jnp — the model's
    historical arithmetic) and `kernels.paged_attention.rope_frequencies`
    (host-side numpy — the kernel's trace-invariant literal) are twins on
    purpose: they cannot be one function because XLA's `pow` rounds up to
    2 ulp away from numpy's, and each side needs its own rounding (the
    kernel for its bit-for-bit dispatch-vs-reference contract, the model
    because moving it onto the numpy literal shifts rotations enough to
    flip activation-quant ties). This pin keeps the twins from silently
    drifting apart: any formula change shows up as a >2-ulp gap."""
    from repro.kernels.paged_attention import rope_frequencies as kern_freqs
    from repro.models.layers import rope_frequencies as model_freqs

    for head_dim in (32, 64, 128):
        for theta in (10_000.0, 500_000.0, 1_000_000.0):
            a = np.asarray(kern_freqs(head_dim, theta), np.float32)
            b = np.asarray(model_freqs(head_dim, theta), np.float32)
            assert a.shape == b.shape == (head_dim // 2,)
            ulp = np.abs(a.view(np.int32) - b.view(np.int32))
            assert ulp.max() <= 2, (head_dim, theta, ulp.max())


def test_ops_dispatch_reference_mode():
    from repro.kernels import ops
    x = jax.random.normal(KEY, (4, 128))
    with ops.use_kernels(False):
        y1 = ops.block_hadamard(x, 32)
    y2 = ops.block_hadamard(x, 32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
