"""Substrate tests: optimizer, train step, data pipeline, checkpointing,
fault-tolerant driver, serving scheduler, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.data.pipeline import (ByteCorpus, DataConfig, Prefetcher,
                                 SyntheticCorpus, batch_iterator)
from repro.distributed import compression as COMP
from repro.models.transformer import build_model
from repro.optim import adamw
from repro.runtime.driver import (ElasticMesh, RuntimeConfig, StepStats,
                                  TrainDriver)
from repro.serve.step import BatchScheduler, Request, make_decode_step
from repro.train.step import TrainConfig, make_train_step


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("llama3-1b").reduced(n_layers=2, d_model=64,
                                          vocab=256, d_ff=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _data(cfg, n=4, batch=4, seq=32):
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    it = batch_iterator(corpus, DataConfig(vocab=cfg.vocab, seq_len=seq,
                                           batch_size=batch))
    return [next(it) for _ in range(n)]


# ---------------- optimizer / train step ----------------

def test_train_loss_decreases(small_lm):
    cfg, model, params = small_lm
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(model, opt_cfg,
                                   TrainConfig(num_microbatches=1,
                                               remat=False)))
    opt = adamw.init_state(opt_cfg, params)
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    it = batch_iterator(corpus, DataConfig(vocab=cfg.vocab, seq_len=32,
                                           batch_size=8))
    losses = []
    for i in range(60):
        params, opt, m = step(params, opt, next(it))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2, \
        (np.mean(losses[:10]), np.mean(losses[-10:]))


def test_microbatched_grads_match_full_batch(small_lm):
    cfg, model, params = small_lm
    opt_cfg = adamw.AdamWConfig(grad_clip=0.0)
    batch = _data(cfg, n=1, batch=8)[0]

    def run(n_micro):
        step = make_train_step(model, opt_cfg,
                               TrainConfig(num_microbatches=n_micro,
                                           remat=False))
        opt = adamw.init_state(opt_cfg, params)
        p2, _, m = step(params, opt, batch)
        return p2, m

    p1, m1 = run(1)
    p2, m2 = run(4)
    # same update up to f32 accumulation order
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p1, p2)
    assert max(jax.tree.leaves(diffs)) < 5e-3


def test_remat_matches_no_remat(small_lm):
    cfg, model, params = small_lm
    batch = _data(cfg, n=1)[0]
    l1, _ = model.loss_fn(params, batch, remat=False)
    l2, _ = model.loss_fn(params, batch, remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_lr_schedule_shapes():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            schedule="cosine", min_lr_ratio=0.1)
    lrs = [float(adamw.lr_at(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup
    assert lrs[2] >= lrs[3] >= lrs[4]        # decay
    assert abs(lrs[4] - 0.1) < 1e-5          # floor


# ---------------- data ----------------

def test_synthetic_corpus_deterministic():
    c1 = SyntheticCorpus(128, seed=3)
    c2 = SyntheticCorpus(128, seed=3)
    r1 = np.random.default_rng(0)
    r2 = np.random.default_rng(0)
    np.testing.assert_array_equal(c1.sample(r1, 64), c2.sample(r2, 64))


def test_host_sharding_disjoint():
    corpus = SyntheticCorpus(64, seed=0)
    b0 = next(batch_iterator(corpus, DataConfig(64, 16, 4, host_id=0,
                                                num_hosts=2)))
    b1 = next(batch_iterator(corpus, DataConfig(64, 16, 4, host_id=1,
                                                num_hosts=2)))
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_labels_are_shifted_tokens():
    corpus = SyntheticCorpus(64, seed=0)
    b = next(batch_iterator(corpus, DataConfig(64, 16, 2)))
    assert b["tokens"].shape == b["labels"].shape == (2, 16)
    # labels[t] is the next token: tokens[1:] == labels[:-1]
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_byte_corpus(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_bytes(b"hello world, this is a tiny corpus for testing" * 10)
    c = ByteCorpus(str(p))
    s = c.sample(np.random.default_rng(0), 32)
    assert s.shape == (32,) and s.dtype == np.int32 and s.max() < 256


def test_prefetcher():
    it = iter([{"x": i} for i in range(5)])
    out = list(Prefetcher(it, depth=2))
    assert [o["x"] for o in out] == [0, 1, 2, 3, 4]


# ---------------- checkpoint ----------------

def test_checkpoint_roundtrip(tmp_path, small_lm):
    _, model, params = small_lm
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"params": params, "opt": {"step": jnp.asarray(3)}}
    mgr.save(100, tree, blocking=True)
    restored = mgr.restore(target=tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path, small_lm):
    _, _, params = small_lm
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"p": jnp.ones((4,)) * s}, blocking=True)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4
    r = mgr.restore(target={"p": jnp.zeros((4,))})
    np.testing.assert_array_equal(np.asarray(r["p"]), 4 * np.ones(4))


def test_checkpoint_async_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, {"a": jnp.arange(10)})
    mgr.wait()
    assert mgr.latest_step() == 7


def test_checkpoint_restore_sharded_single_device(tmp_path):
    """Elastic-restart path: restore with new (here trivial) shardings."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree, blocking=True)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    shardings = {"w": NamedSharding(mesh, P("data", "model"))}
    out = mgr.restore_sharded(tree, shardings)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


# ---------------- runtime driver ----------------

def test_driver_retries_and_recovers(tmp_path, small_lm):
    cfg, model, params = small_lm
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    opt = adamw.init_state(opt_cfg, params)
    base_step = jax.jit(make_train_step(model, opt_cfg,
                                        TrainConfig(remat=False)))
    calls = {"n": 0}

    def flaky_step(p, o, b):
        calls["n"] += 1
        if calls["n"] == 3:          # one transient failure
            raise RuntimeError("injected transient device error")
        return base_step(p, o, b)

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(0, {"params": params, "opt": opt}, blocking=True)
    driver = TrainDriver(flaky_step, mgr,
                         RuntimeConfig(checkpoint_every=4, max_retries=2))
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    it = batch_iterator(corpus, DataConfig(cfg.vocab, 32, 4))
    (p2, o2), step = driver.run(params, opt, it, num_steps=8)
    assert step == 8
    assert driver.failures == 1          # retried once, then succeeded
    assert mgr.latest_step() == 8


def test_driver_restores_after_persistent_failure(tmp_path, small_lm):
    cfg, model, params = small_lm
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    opt = adamw.init_state(opt_cfg, params)
    base_step = jax.jit(make_train_step(model, opt_cfg,
                                        TrainConfig(remat=False)))
    calls = {"n": 0}

    def dying_step(p, o, b):
        calls["n"] += 1
        if calls["n"] in (4, 5, 6, 7):   # persistent across retries, once
            raise RuntimeError("injected persistent failure")
        return base_step(p, o, b)

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(0, {"params": params, "opt": opt}, blocking=True)
    driver = TrainDriver(dying_step, mgr,
                         RuntimeConfig(checkpoint_every=2, max_retries=1))
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    it = batch_iterator(corpus, DataConfig(cfg.vocab, 32, 4))
    (p2, o2), step = driver.run(params, opt, it, num_steps=6)
    assert driver.restores >= 1
    assert step == 6


def test_straggler_detection():
    stats = StepStats()
    flagged = []
    for i in range(30):
        dt = 1.0 if i != 25 else 5.0
        if stats.record(i, dt, factor=2.5, alpha=0.1):
            flagged.append(i)
    assert flagged == [25]


def test_elastic_mesh_sizing():
    em = ElasticMesh(model_parallel=4)
    assert em.shape_for(32) == (8, 4)
    assert em.shape_for(28) == (4, 4)    # degraded pod → next pow2 data dim
    assert em.shape_for(4) == (1, 4)


# ---------------- gradient compression ----------------

def test_ef_compression_preserves_signal():
    grads = {"w": jnp.asarray(np.random.default_rng(0)
                              .standard_normal((64, 64)), jnp.float32)}
    ef = COMP.init_ef_state(grads)
    # accumulated dequantized grads + residual == accumulated true grads
    total_true = np.zeros((64, 64))
    total_deq = np.zeros((64, 64))
    for i in range(10):
        g = {"w": grads["w"] * (1 + 0.1 * i)}
        deq, ef = COMP.ef_compress_grads(g, ef)
        total_true += np.asarray(g["w"])
        total_deq += np.asarray(deq["w"])
    resid = np.asarray(ef["w"])
    np.testing.assert_allclose(total_deq + resid, total_true, atol=1e-3)


def test_ef_single_step_error_bounded():
    g = {"w": jnp.asarray(np.random.default_rng(1)
                          .standard_normal((128,)), jnp.float32)}
    ef = COMP.init_ef_state(g)
    deq, ef2 = COMP.ef_compress_grads(g, ef)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert float(jnp.max(jnp.abs(deq["w"] - g["w"]))) <= scale / 2 + 1e-7


# ---------------- serving ----------------

def test_batch_scheduler_matches_sequential_decode(small_lm):
    cfg, model, params = small_lm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).tolist()
               for n in (5, 3, 7)]

    # reference: one-at-a-time greedy generation
    def generate(prompt, max_new):
        cache = model.init_cache(1, 64, dtype=jnp.float32)
        dec = make_decode_step(model)
        toks = list(prompt)
        out = []
        for i, t in enumerate(toks):
            nxt, _, cache = dec(params, jnp.asarray([[t]], jnp.int32), cache,
                                jnp.asarray(i, jnp.int32))
        for j in range(max_new):
            t = int(nxt[0, 0])
            out.append(t)
            nxt, _, cache = dec(params, jnp.asarray([[t]], jnp.int32), cache,
                                jnp.asarray(len(toks) + j, jnp.int32))
        return out

    want = [generate(p, 4) for p in prompts]

    sched = BatchScheduler(model, params, slots=2, max_len=64)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=4))
    done = sched.run()
    got = {r.rid: r.generated[:4] for r in done}
    assert len(done) == 3
    for i in range(3):
        assert got[i] == want[i], (i, got[i], want[i])


def test_batch_scheduler_temperature_uses_rng(small_lm):
    """Regression: `step()` must thread a per-step PRNG key into the
    decode fn — without it temperature > 0 silently degrades to argmax."""
    cfg, model, params = small_lm

    def run(temperature, seed=0):
        sched = BatchScheduler(model, params, slots=2, max_len=64,
                               temperature=temperature, seed=seed)
        for i in range(2):
            sched.submit(Request(rid=i, prompt=[5, 9, 3], max_new=12))
        return {r.rid: r.generated for r in sched.run()}

    greedy = run(0.0)
    hot_a = run(8.0, seed=0)
    hot_b = run(8.0, seed=1)
    # at high temperature sampling must diverge from argmax...
    assert hot_a != greedy
    # ...and be reproducible for a fixed seed, seed-dependent otherwise
    assert hot_a == run(8.0, seed=0)
    assert hot_a != hot_b


def _engine_generate(small_lm, sampling_kw, *, seed=0, n_req=2, max_new=8):
    from repro.serve.engine import (EngineRequest, SamplingParams,
                                    ServeEngine, as_servable)
    cfg, model, params = small_lm
    eng = ServeEngine(as_servable(model, params), n_pages=33, page_size=8,
                      max_seqs=2, prefill_chunk=4, seed=seed)
    for i in range(n_req):
        eng.submit(EngineRequest(
            rid=i, prompt=[5 + i, 9, 3],
            sampling=SamplingParams(max_new=max_new, **sampling_kw)))
    return {r.rid: r for r in eng.run()}, eng


def test_engine_top_k1_equals_greedy(small_lm):
    """top_k=1 collapses sampling to argmax even at high temperature —
    the fused `_sample_tokens` filter must win over the categorical."""
    greedy, _ = _engine_generate(small_lm, {"temperature": 0.0})
    top1, _ = _engine_generate(small_lm, {"temperature": 8.0, "top_k": 1})
    for rid in greedy:
        assert top1[rid].generated == greedy[rid].generated


def test_engine_top_p_tiny_equals_greedy(small_lm):
    """A nucleus smaller than the top token's probability keeps exactly
    the argmax token."""
    greedy, _ = _engine_generate(small_lm, {"temperature": 0.0})
    nucleus, _ = _engine_generate(small_lm, {"temperature": 0.5,
                                             "top_p": 1e-6})
    for rid in greedy:
        assert nucleus[rid].generated == greedy[rid].generated


def test_engine_top_k_sampling_stochastic_and_reproducible(small_lm):
    """With a wide top-k at high temperature the engine must still sample
    (diverge from greedy), reproduce for a fixed seed, and respect the
    filter (every token inside the per-step top-k set)."""
    greedy, _ = _engine_generate(small_lm, {"temperature": 0.0})
    kw = {"temperature": 8.0, "top_k": 50}
    hot_a, _ = _engine_generate(small_lm, kw, seed=0)
    hot_b, _ = _engine_generate(small_lm, kw, seed=1)
    gen = lambda d: [d[r].generated for r in sorted(d)]
    assert gen(hot_a) != gen(greedy)
    assert gen(hot_a) == gen(_engine_generate(small_lm, kw, seed=0)[0])
    assert gen(hot_a) != gen(hot_b)


def test_engine_rejects_bad_sampling_params(small_lm):
    from repro.serve.engine import (EngineRequest, SamplingParams,
                                    ServeEngine, as_servable)
    cfg, model, params = small_lm
    eng = ServeEngine(as_servable(model, params), n_pages=17, page_size=8)
    for bad in ({"top_k": -1}, {"top_p": 0.0}, {"top_p": 1.5},
                {"stop": ((),)}):
        with pytest.raises(ValueError):
            eng.submit(EngineRequest(rid=0, prompt=[1, 2],
                                     sampling=SamplingParams(**bad)))


def test_engine_stop_sequences_halt_generation(small_lm):
    """Per-request stop sequences end generation at the first suffix
    match (the matched tokens are kept), pages are freed, and a
    multi-token stop only fires on the full contiguous match."""
    greedy, _ = _engine_generate(small_lm, {}, n_req=1, max_new=8)
    full = greedy[0].generated
    assert len(full) == 8

    def expected_cut(stop_seq):
        n = len(stop_seq)
        for i in range(n, len(full) + 1):
            if full[i - n:i] == list(stop_seq):
                return full[:i]
        return full

    one_tok = (full[2],)
    multi = tuple(full[1:3])
    for stop in (one_tok, multi):
        got, eng = _engine_generate(small_lm, {"stop": (stop,)},
                                    n_req=1, max_new=8)
        want = expected_cut(stop)
        assert got[0].generated == want, (stop, got[0].generated, want)
        assert got[0].stop_hit == (len(want) < 8)
        assert eng.kv.allocator.n_free == eng.kv.allocator.capacity
        assert not eng.kv.tables


def test_batch_scheduler_slot_reuse_matches_fresh(small_lm):
    """Regression: a readmitted request landing in a previously used slot
    (stale KV, pos reset to 0) must decode exactly as on a fresh
    scheduler."""
    cfg, model, params = small_lm
    prompts = [[3, 14, 15, 92, 6], [53, 58, 9, 7], [61, 2, 44]]

    # slots=1 forces requests 1 and 2 to reuse request 0's slot
    sched = BatchScheduler(model, params, slots=1, max_len=64)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=5))
    got = {r.rid: r.generated for r in sched.run()}

    for i, p in enumerate(prompts):
        fresh = BatchScheduler(model, params, slots=1, max_len=64)
        fresh.submit(Request(rid=i, prompt=p, max_new=5))
        want = fresh.run()[0].generated
        assert got[i] == want, (i, got[i], want)
