"""Per-architecture smoke tests on reduced configs (CPU): one forward/train
step, shape + finiteness checks, and decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models.config import applicable_shapes
from repro.models.transformer import build_model

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _smoke_batch(cfg, key, batch=B, seq=S):
    ks = jax.random.split(key, 3)
    if cfg.frontend == "audio_frames":
        return {
            "frames": jax.random.normal(ks[0], (batch, seq, 512)),
            "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab),
        }
    if cfg.frontend == "vision_patches":
        npatch = cfg.frontend_tokens
        ntext = seq - npatch
        return {
            "patches": jax.random.normal(ks[0], (batch, npatch, 1024)),
            "tokens": jax.random.randint(ks[1], (batch, ntext), 0, cfg.vocab),
            "labels": jax.random.randint(ks[2], (batch, ntext), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab),
    }


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            if cfg.uses_moe:
                # no-drop capacity so decode routing == full-seq routing
                # (capacity drops are a real train/serve discrepancy of
                # capacity-based MoE; the consistency invariant needs them off)
                cfg = cfg.reduced(capacity_factor=cfg.n_experts / cfg.top_k)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(1))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(models, arch):
    cfg, model, params = models(arch)
    batch = _smoke_batch(cfg, KEY)
    logits = model.forward(params, batch)
    seq = S if cfg.frontend != "vision_patches" else S
    assert logits.shape == (B, seq, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_loss_and_grad_step(models, arch):
    cfg, model, params = models(arch)
    batch = _smoke_batch(cfg, KEY)
    (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
        params, batch)
    assert bool(jnp.isfinite(loss))
    gn = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        grads, 0.0)
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).has_decode])
def test_decode_matches_forward(models, arch):
    """prefill(S−1) + decode_step == forward logits at the last position."""
    cfg, model, params = models(arch)
    batch = _smoke_batch(cfg, KEY)
    full = model.forward(params, batch).astype(jnp.float32)

    if cfg.frontend == "vision_patches":
        prompt = {"patches": batch["patches"],
                  "tokens": batch["tokens"][:, :-1]}
        last_tok = batch["tokens"][:, -1:]
    else:
        prompt = {"tokens": batch["tokens"][:, :-1]}
        last_tok = batch["tokens"][:, -1:]

    cache = model.init_cache(B, S, dtype=jnp.float32)
    _, cache = model.prefill(params, prompt, cache)
    logits, _ = model.decode_step(params, last_tok, cache,
                                  jnp.asarray(S - 1, jnp.int32))
    want = full[:, -1]
    got = logits.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-2, atol=5e-2)
    # argmax agreement is the serving-level invariant
    assert (np.argmax(np.asarray(got), -1)
            == np.argmax(np.asarray(want), -1)).mean() >= 0.95


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_applicable_shapes_defined(arch):
    cfg = get_config(arch)
    cells = applicable_shapes(cfg)
    names = [c.name for c in cells]
    assert "train_4k" in names and "prefill_32k" in names
    if cfg.family == "encoder":
        assert "decode_32k" not in names
    if cfg.family in ("ssm", "hybrid"):
        assert "long_500k" in names
    else:
        assert "long_500k" not in names


def test_moe_gather_dispatch_matches_dense_oracle():
    """With capacity ≥ S (no drops) the gather dispatch must equal the
    evaluate-all-experts oracle exactly."""
    from repro.models import moe as M
    d, e, f, k = 16, 8, 32, 2
    p = M.init_moe(jax.random.PRNGKey(0), d, e, f, 1, "silu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, d))
    got = M.moe_ffn(x, p, n_experts=e, top_k=k, capacity_factor=e / k,
                    act="silu")
    want = M.moe_ffn_dense_oracle(x, p, n_experts=e, top_k=k, act="silu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens_gracefully():
    from repro.models import moe as M
    d, e, f, k = 16, 4, 32, 2
    p = M.init_moe(jax.random.PRNGKey(0), d, e, f, 0, "silu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, d))
    out = M.moe_ffn(x, p, n_experts=e, top_k=k, capacity_factor=0.5,
                    act="silu")
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_ssd_chunked_matches_sequential():
    """Chunked SSD == naive sequential recurrence."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p_dim, n = 2, 48, 3, 8, 4
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p_dim))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    y, state = ssd_chunked(x, dt, a, bm, cm, chunk=16)

    # sequential oracle
    hstate = np.zeros((b, h, n, p_dim), np.float64)
    xs, dts, bs, cs = map(np.asarray, (x, dt, bm, cm))
    av = np.asarray(a)
    ys = np.zeros((b, s, h, p_dim))
    for t in range(s):
        decay = np.exp(dts[:, t] * av)                       # [b,h]
        upd = np.einsum("bn,bh,bhp->bhnp", bs[:, t], dts[:, t], xs[:, t])
        hstate = hstate * decay[:, :, None, None] + upd
        ys[:, t] = np.einsum("bn,bhnp->bhp", cs[:, t], hstate)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(state), hstate, rtol=2e-3, atol=2e-3)


def test_ssm_block_chunked_carry_matches_recurrent_decode():
    """Carried-state prefill parity: feeding a sequence through
    `ssm_block` in several chunks with the cache carried across calls
    must match a stepwise s==1 recurrent decode loop — including when the
    final chunk is right-padded and `valid_len` masks the tail (the
    serving engine's chunked-prefill path)."""
    from repro.models import ssm as S

    cfg_d, expand, head_dim, state, width = 32, 2, 8, 4, 4
    p = S.init_ssm(jax.random.PRNGKey(0), cfg_d, expand=expand,
                   head_dim=head_dim, state=state, conv_width=width,
                   dtype=jnp.float32)
    b, s = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg_d))

    def fresh_cache():
        return S.init_ssm_cache(b, cfg_d, expand=expand, head_dim=head_dim,
                                state=state, conv_width=width,
                                dtype=jnp.float32)

    # stepwise recurrent decode — the exact reference
    cache = fresh_cache()
    ys = []
    for t in range(s):
        y, cache = S.ssm_block(x[:, t:t + 1], p, head_dim=head_dim,
                               state=state, chunk=8, cache=cache)
        ys.append(y)
    want = jnp.concatenate(ys, axis=1)
    want_cache = cache

    # chunked with carried state; last chunk right-padded to 8 with
    # valid_len=4 masking the garbage tail out of the carried state
    cache = fresh_cache()
    y1, cache = S.ssm_block(x[:, :8], p, head_dim=head_dim, state=state,
                            chunk=4, cache=cache)
    xpad = jnp.concatenate(
        [x[:, 8:], jnp.ones((b, 4, cfg_d), x.dtype) * 7.7], axis=1)
    y2, cache = S.ssm_block(xpad, p, head_dim=head_dim, state=state,
                            chunk=4, cache=cache,
                            valid_len=jnp.asarray([4, 4], jnp.int32))
    got = jnp.concatenate([y1, y2[:, :4]], axis=1)

    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache["state"]),
                               np.asarray(want_cache["state"]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache["conv"]),
                               np.asarray(want_cache["conv"]),
                               rtol=1e-5, atol=1e-5)


def test_chunked_attention_matches_dense():
    from repro.models.layers import _chunked_attention, _dense_attention
    b, s, h, kh, dh = 2, 40, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kh, dh))
    v = jax.random.normal(ks[2], (b, s, kh, dh))
    for causal in (True, False):
        got = _chunked_attention(q, k, v, causal=causal, chunk_q=16,
                                 chunk_kv=8)
        want = _dense_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
