"""Tiered KV paging: host swap tier unit tests + engine-level invariants.

The swap tier's contract, exercised at both layers:

  * `HostSwapPool` / `PagedKVCache.swap_out/.swap_in` — page-granular,
    bit-exact round trips through the numpy mirror; residency ledger
    transitions (device → host → device); exclusivity (shared pages
    never move); failure atomicity (an allocation failure mid-swap
    mutates nothing); the scrub/COW guards that keep host-resident and
    in-flight pages untouchable.
  * `ServeEngine` with a host tier — a pool too small for the offered
    load completes every request with tokens bit-identical to an
    unpressured baseline, whichever recovery mode pressure picks
    (swap-to-host, recompute-by-replay, or the cost model's mix);
    injected `SwapFault`s drive retry-with-backoff, then fallback to
    replay, then terminal failure past the preemption bound; the books
    (device pages, host slots, commitments) balance after every step.

The chaos test composes swap faults with the existing exhaustion /
cancel / lifecycle chaos under `FAULT_SEED`-offset seeds, mirroring
`test_faults.py`.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.transformer import build_model
from repro.serve.engine import (EngineRequest, FaultPlan, HostSwapPool,
                                SamplingParams, ServeEngine, as_servable)
from repro.serve.engine.pages import PagedKVCache

MAX_NEW = 5
PROMPTS = [[3, 14, 15, 92, 6], [53, 58, 9], [7, 9, 3, 23, 84, 62, 43],
           [41, 5, 27, 18, 2, 88, 31, 7, 64]]
GEOM = dict(n_pages=33, page_size=4, max_seqs=2, prefill_chunk=4)
# genuinely undersized: 4 usable pages < two concurrent worst cases
PRESSURE = dict(n_pages=5, page_size=4, max_seqs=2, prefill_chunk=4,
                max_preemptions=10)


# ----------------------------------------------------------------------
# cache-level units
# ----------------------------------------------------------------------

def make_cache(n_pages=8, page_size=4, nl=2, kh=2, dh=4):
    rng = np.random.default_rng(0)
    shape = (nl, n_pages, page_size, kh, dh)
    kv = {"k": jnp.asarray(rng.standard_normal(shape), jnp.float32),
          "v": jnp.asarray(rng.standard_normal(shape), jnp.float32)}
    return PagedKVCache(kv, n_pages, page_size)


def test_host_pool_capacity_and_freelist():
    cache = make_cache()
    # page_bytes: both leaves, nl * page_size * kh * dh * 4 bytes each
    assert cache.page_bytes == 2 * 2 * 4 * 2 * 4 * 4
    pool = HostSwapPool(cache.state["kv"], 5 * cache.page_bytes)
    assert pool.capacity == 5 and pool.n_free == 5 and pool.in_use == 0
    slots = pool.take(3)
    assert len(set(slots)) == 3 and pool.in_use == 3
    with pytest.raises(MemoryError, match="host swap tier exhausted"):
        pool.take(3)
    assert pool.n_free == 2           # failed take mutated nothing
    pool.release(slots[:2])
    assert pool.in_use == 1
    with pytest.raises(ValueError, match="double/invalid release"):
        pool.release([slots[0]])
    with pytest.raises(ValueError, match="double/invalid release"):
        pool.release([slots[2], slots[2]])
    assert pool.in_use == 1           # failed release mutated nothing
    # a budget smaller than one page disables the tier gracefully
    assert HostSwapPool(cache.state["kv"], 3).capacity == 0


def test_swap_roundtrip_bit_identical():
    cache = make_cache()
    cache.attach_host_pool(64)
    cache.open(0)
    cache.ensure(0, 12)               # 3 pages at page_size 4
    pages = list(cache.tables[0])
    before = {k: np.asarray(leaf[:, pages])
              for k, leaf in cache.state["kv"].items()}

    n, nbytes = cache.swap_out(0)
    assert (n, nbytes) == (3, 3 * cache.page_bytes)
    assert cache.residency(0) == ["host"] * 3
    assert cache.allocator.in_use == 0          # device copies freed
    assert cache.host_pool.in_use == 3
    assert not cache._inflight
    with pytest.raises(ValueError, match="host-resident"):
        cache.block_table_array([0], 4)
    # idempotent: nothing device-resident left to move
    assert cache.swap_out(0) == (0, 0)

    n, nbytes = cache.swap_in(0)
    assert (n, nbytes) == (3, 3 * cache.page_bytes)
    assert cache.residency(0) == ["device"] * 3
    assert cache.host_pool.in_use == 0 and not cache._inflight
    new_pages = list(cache.tables[0])
    after = {k: np.asarray(leaf[:, new_pages])
             for k, leaf in cache.state["kv"].items()}
    for k in before:
        np.testing.assert_array_equal(before[k], after[k])
    assert cache.swap_in(0) == (0, 0)
    cache.release(0)
    assert cache.allocator.in_use == 0


def test_shared_pages_stay_device_resident():
    cache = make_cache()
    cache.attach_host_pool(64)
    cache.open(0)
    cache.ensure(0, 12)
    shared = cache.tables[0][0]
    cache.allocator.incref([shared])  # a radix-tree (or sibling) holder
    assert cache.swap_eligible_pages(0) == cache.tables[0][1:]
    n, _ = cache.swap_out(0)
    assert n == 2
    assert cache.residency(0) == ["device", "host", "host"]
    assert cache.tables[0][0] == shared
    cache.swap_in(0)
    assert cache.residency(0) == ["device"] * 3
    cache.deref([shared])
    cache.release(0)
    assert cache.allocator.in_use == 0 and cache.host_pool.in_use == 0


def test_swap_in_alloc_failure_mutates_nothing():
    cache = make_cache(n_pages=5)     # 4 usable pages
    cache.attach_host_pool(64)
    cache.open(0)
    cache.ensure(0, 12)               # 3 pages
    cache.swap_out(0)
    cache.open(1)
    cache.ensure(1, 16)               # the other sequence takes all 4
    with pytest.raises(MemoryError):
        cache.swap_in(0)
    assert cache.residency(0) == ["host"] * 3   # table untouched
    assert cache.host_pool.in_use == 3          # host slots retained
    assert cache.allocator.in_use == 4
    cache.release(1)
    cache.swap_in(0)                  # recovers once pages free up
    assert cache.residency(0) == ["device"] * 3
    cache.release(0)
    assert cache.host_pool.in_use == 0


def test_release_returns_host_slots():
    """Releasing a swapped-out sequence (cancel/expire/degrade-to-replay
    while host-resident) returns its host slots without any device work."""
    cache = make_cache()
    cache.attach_host_pool(64)
    cache.open(0)
    cache.ensure(0, 12)
    cache.swap_out(0)
    assert cache.host_pool.in_use == 3
    cache.release(0)
    assert 0 not in cache.tables
    assert cache.host_pool.in_use == 0 and cache.allocator.in_use == 0


def test_scrub_and_cow_guards():
    cache = make_cache()
    cache.attach_host_pool(64)
    cache.open(0)
    cache.ensure(0, 12)
    page = cache.tables[0][0]
    with pytest.raises(AssertionError, match="still-referenced"):
        cache.scrub([page], None)
    cache._inflight.add(page)
    try:
        with pytest.raises(AssertionError, match="in-flight"):
            cache.cow_copy(page, cache.tables[0][1])
    finally:
        cache._inflight.discard(page)
    cache.swap_out(0)
    # a swapped page's device id was freed: COW from it must refuse
    with pytest.raises(AssertionError, match="unallocated"):
        cache.cow_copy(page, 7)


# ----------------------------------------------------------------------
# engine-level invariants
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def adapter():
    cfg = get_config("llama3-1b").reduced()
    model = build_model(cfg)
    return as_servable(model, model.init(jax.random.PRNGKey(0)))


def _submit_all(eng):
    for rid, p in enumerate(PROMPTS):
        eng.submit(EngineRequest(rid=rid, prompt=list(p),
                                 sampling=SamplingParams(max_new=MAX_NEW)))


def _run_checked(eng):
    done = []
    while eng.queue or eng.active:
        done.extend(eng.step())
        eng.check_books()
    return {r.rid: r for r in done}


def _assert_drained(eng):
    alloc = eng.kv.allocator
    assert alloc.in_use == 0 and alloc.n_free == alloc.capacity
    assert not eng.kv.tables and not eng.kv.slots
    assert not eng._committed and eng._committed_total == 0
    hp = eng.kv.host_pool
    assert hp is None or hp.in_use == 0
    eng.check_books()


def _counter(eng, name):
    return eng.metrics.counter(name).value


@pytest.fixture(scope="module")
def baseline(adapter):
    eng = ServeEngine(adapter, **GEOM)
    _submit_all(eng)
    done = _run_checked(eng)
    _assert_drained(eng)
    assert all(done[r].outcome == "length" for r in done)
    return {r: done[r].generated for r in done}


def test_swap_under_pressure_bit_identical(adapter, baseline):
    """Policy `always` on an undersized pool: victims swap out and back
    with zero replayed tokens, every request completes, and the tokens
    match the unpressured baseline bit for bit."""
    eng = ServeEngine(adapter, **PRESSURE, swap_host_mb=8,
                      swap_policy="always")
    _submit_all(eng)
    done = _run_checked(eng)
    _assert_drained(eng)
    assert _counter(eng, "engine.swap.out") >= 1
    assert _counter(eng, "engine.swap.in") >= 1
    assert _counter(eng, "engine.swap.bytes") > 0
    assert _counter(eng, "engine.swap.fallbacks") == 0
    assert _counter(eng, "engine.replayed_prefill_tokens") == 0
    for rid, toks in baseline.items():
        assert done[rid].outcome == "length"
        assert done[rid].generated == toks, rid


def test_swap_policy_never_only_preempts(adapter, baseline):
    """`never` (even with a budget offered) keeps the recompute path:
    no host pool, zero swap traffic, preemptions as before."""
    eng = ServeEngine(adapter, **PRESSURE, swap_host_mb=8,
                      swap_policy="never")
    assert eng.kv.host_pool is None
    _submit_all(eng)
    done = _run_checked(eng)
    _assert_drained(eng)
    assert _counter(eng, "engine.preemptions") >= 1
    assert _counter(eng, "engine.swap.out") == 0
    assert _counter(eng, "engine.swap.in") == 0
    for rid, toks in baseline.items():
        assert done[rid].generated == toks, rid


@pytest.mark.parametrize("break_even,expect_swap", [
    (0.0, False),      # swap never pays: every eviction recomputes
    (1e9, True),       # swap always pays: every eviction offloads
])
def test_cost_policy_follows_break_even(adapter, baseline, break_even,
                                        expect_swap):
    eng = ServeEngine(adapter, **PRESSURE, swap_host_mb=8,
                      swap_policy="cost",
                      swap_break_even_bytes_per_token=break_even)
    _submit_all(eng)
    done = _run_checked(eng)
    _assert_drained(eng)
    if expect_swap:
        assert _counter(eng, "engine.swap.out") >= 1
        assert _counter(eng, "engine.preemptions") == 0
    else:
        assert _counter(eng, "engine.swap.out") == 0
        assert _counter(eng, "engine.preemptions") >= 1
    for rid, toks in baseline.items():
        assert done[rid].generated == toks, rid


def _drive_until_swapped_out(eng):
    """Step until the first swap-out lands; returns the next step index."""
    _submit_all(eng)
    done = []
    while _counter(eng, "engine.swap.out") == 0:
        assert eng.queue or eng.active, "run ended without any swap-out"
        done.extend(eng.step())
        eng.check_books()
    return done


def test_swap_in_faults_retry_with_backoff(adapter, baseline):
    """Transient swap-in faults are retried with backoff, not replayed:
    the victim still swaps in (zero recomputed tokens) once the tier
    heals, bit-identically."""
    eng = ServeEngine(adapter, **PRESSURE, swap_host_mb=8,
                      swap_policy="always")
    done = _drive_until_swapped_out(eng)
    s = eng._step_index
    eng.faults = FaultPlan(swap_fail_steps=(s, s + 1))
    while eng.queue or eng.active:
        done.extend(eng.step())
        eng.check_books()
    done = {r.rid: r for r in done}
    _assert_drained(eng)
    assert _counter(eng, "engine.swap.retries") >= 1
    assert _counter(eng, "engine.swap.in") >= 1
    assert _counter(eng, "engine.swap.fallbacks") == 0
    assert _counter(eng, "engine.replayed_prefill_tokens") == 0
    for rid, toks in baseline.items():
        assert done[rid].generated == toks, rid


def test_swap_out_fault_degrades_to_preempt(adapter, baseline):
    """A SwapFault during swap-out falls through to plain preemption in
    the same exhaustion event — degraded service, identical tokens."""
    eng = ServeEngine(adapter, **PRESSURE, swap_host_mb=8,
                      swap_policy="always",
                      faults=FaultPlan(swap_fail_rate=1.0))
    _submit_all(eng)
    done = _run_checked(eng)
    _assert_drained(eng)
    assert _counter(eng, "engine.swap.fallbacks") >= 1
    assert _counter(eng, "engine.preemptions") >= 1
    for rid, toks in baseline.items():
        assert done[rid].generated == toks, rid


def test_swap_in_abandoned_fails_terminally(adapter, baseline):
    """Retries exhausted → fallback to replay; past the preemption bound
    the victim fails terminally with a diagnosable reason, its host
    slots returned. Everyone else is untouched."""
    eng = ServeEngine(adapter, n_pages=5, page_size=4, max_seqs=2,
                      prefill_chunk=4, max_preemptions=0,
                      swap_host_mb=8, swap_policy="always",
                      swap_max_retries=0)
    done = _drive_until_swapped_out(eng)
    s = eng._step_index
    eng.faults = FaultPlan(swap_fail_steps=tuple(range(s, s + 64)))
    while eng.queue or eng.active:
        done.extend(eng.step())
        eng.check_books()
    done = {r.rid: r for r in done}
    _assert_drained(eng)
    failed = [r for r in done.values() if r.outcome == "failed"]
    assert len(failed) == 1
    assert "swap-in abandoned" in failed[0].failed
    assert _counter(eng, "engine.swap.fallbacks") >= 1
    for rid, req in done.items():
        if req.outcome == "length":
            assert req.generated == baseline[rid], rid


def test_drain_with_swapped_resident(adapter, baseline):
    """drain() honors a swapped-out resident: it swaps back in and
    completes (it was admitted work), never-admitted queue entries
    cancel, and every tier comes back empty."""
    eng = ServeEngine(adapter, **PRESSURE, swap_host_mb=8,
                      swap_policy="always")
    done = _drive_until_swapped_out(eng)
    done.extend(eng.drain())
    done = {r.rid: r for r in done}
    _assert_drained(eng)
    assert len(done) == len(PROMPTS)
    for rid, req in done.items():
        if req.outcome == "length":
            assert req.generated == baseline[rid], rid
        else:
            # only never-admitted queue entries may be cancelled
            assert req.outcome == "cancelled" and not req.generated
    with pytest.raises(RuntimeError, match="draining"):
        eng.submit(EngineRequest(rid=99, prompt=[1, 2],
                                 sampling=SamplingParams(max_new=1)))


def test_swap_with_prefix_cache(adapter):
    """Swap composes with the radix cache: shared (tree-held) pages stay
    device resident across a victim's swap, books balance every step,
    and the greedy tokens match a pressure-free prefix run."""
    system = list(range(40, 52))      # 3 full pages at page_size 4
    prompts = [system + p for p in PROMPTS]

    def run(**kw):
        # headroom 0 so two sequences admit concurrently on their prompt
        # pages alone; the large max_new makes decode growth (backed by
        # swap, not commitment) overflow the pressured pool
        eng = ServeEngine(adapter, page_size=4, max_seqs=2,
                          prefill_chunk=4, prefix_cache=True,
                          headroom_pages=0, max_preemptions=10, **kw)
        for rid, p in enumerate(prompts):
            eng.submit(EngineRequest(
                rid=rid, prompt=list(p),
                sampling=SamplingParams(max_new=12)))
        done = _run_checked(eng)
        return eng, {r: done[r].generated for r in done}

    _, base = run(n_pages=65)
    eng, got = run(n_pages=11, swap_host_mb=8, swap_policy="always")
    assert _counter(eng, "engine.swap.out") >= 1
    assert got == base
    eng.prefix_cache.clear()
    _assert_drained(eng)


@pytest.mark.chaos
def test_chaos_with_swap_faults(adapter, baseline):
    """Exhaustion + swap faults + lifecycle chaos, seeds offset by
    FAULT_SEED (the CI matrix dimension): after any interleaving the
    books balance every step, both tiers drain empty, every request
    reaches exactly one terminal state, and completed survivors are
    bit-identical."""
    base_seed = int(os.environ.get("FAULT_SEED", "0"))
    for seed in range(base_seed * 5, base_seed * 5 + 5):
        plan = FaultPlan(seed=seed, exhaust_rate=0.3, swap_fail_rate=0.3,
                         cancel_rate=0.2, dispatch_fail_rate=0.1)
        eng = ServeEngine(adapter, **GEOM, max_preemptions=10,
                          swap_host_mb=8, swap_policy="always",
                          faults=plan)
        _submit_all(eng)
        done = _run_checked(eng)
        _assert_drained(eng)
        assert len(done) == len(PROMPTS)
        outcomes = {rid: done[rid].outcome for rid in done}
        assert all(o in ("length", "cancelled", "expired", "failed")
                   for o in outcomes.values()), (seed, outcomes)
        c = eng.metrics
        assert (c.counter("engine.requests.finished").value
                + c.counter("engine.requests.cancelled").value
                + c.counter("engine.requests.expired").value
                + c.counter("engine.requests.failed").value) == len(PROMPTS)
        # every page that left the device tier came back or was released
        assert (c.counter("engine.swap.in").value
                <= c.counter("engine.swap.out").value)
        for rid, req in done.items():
            if req.outcome == "length":
                assert req.generated == baseline[rid], (seed, rid)


def test_swap_metrics_in_snapshot(adapter):
    """The v4 taxonomy: swap counters and host-tier gauges are present
    (and schema-valid) with and without a host pool attached."""
    from repro.serve.telemetry import validate_snapshot

    eng = ServeEngine(adapter, **GEOM)
    _submit_all(eng)
    _run_checked(eng)
    snap = eng.metrics_snapshot()
    validate_snapshot(snap)
    assert snap["gauges"]["engine.swap.host_pages_capacity"] == 0

    eng = ServeEngine(adapter, **PRESSURE, swap_host_mb=8,
                      swap_policy="always")
    _submit_all(eng)
    _run_checked(eng)
    snap = eng.metrics_snapshot()
    validate_snapshot(snap)
    c, g = snap["counters"], snap["gauges"]
    assert c["engine.swap.out"] >= 1 and c["engine.swap.in"] >= 1
    assert c["engine.swap.bytes"] > 0
    assert c["engine.swap.bytes"] % eng.kv.page_bytes == 0
    assert g["engine.swap.host_pages_capacity"] > 0
    assert g["engine.swap.host_budget_bytes"] == 8 * 2 ** 20
    assert g["engine.swap.host_pages"] == 0      # drained
