"""Serve-time telemetry: metrics, request tracing, and rotation-quality
probes for the paged serving engine.

Dependency-free by construction — `metrics` and `trace` are stdlib-only
(importable from the scheduler hot loop, the benches, or a bare
telemetry shard); `quality` uses jax only for the probe math that runs
inside the served forward. Nothing here changes what the engine
computes: tracing and probes are off by default, and turning them on is
bit-path-neutral (no dispatch-shape or PRNG-key effects — enforced by
the engine parity tests).

Metric taxonomy (schema version {ver}; canonical list + validation in
`schema.py`, exported snapshots are stamped and checked against it):

* ``engine.*`` — scheduler/engine signals.
  Counters: ``engine.steps``, ``engine.prefill_tokens``,
  ``engine.decode_tokens``, ``engine.generated_tokens`` (decode tokens
  plus each request's prefill-sampled first token),
  ``engine.pages_walked`` / ``engine.pages_walked_dense`` (ragged
  early-exit vs padded full-table walk, per attention dispatch),
  ``engine.requests.{{submitted,admitted,finished,stop_hits}}``,
  ``engine.admission.blocked`` (head-of-line blocked on pages).
  Gauges: ``engine.pages.{{capacity,in_use,peak_in_use,reserved,
  scrubbed}}`` (allocator levels + high-water mark + scrub total),
  ``engine.register_slots.*`` (same, SSM/hybrid specs only),
  ``engine.queue.depth``, ``engine.batch.{{decoding,prefilling}}``.
  Histograms: ``engine.step.wall_s``,
  ``engine.step.budget_utilization`` (tokens spent / token budget),
  ``engine.decode.batch_occupancy`` (decode rows / max_seqs, observed
  per decode dispatch), ``engine.decode.token_latency_s`` (each
  generated token inherits its engine step's wall time),
  ``engine.admission.wait_s`` (submit → admission),
  ``engine.request.e2e_s`` (submit → finish),
  ``engine.prefill.chunk_tokens`` (real tokens per prefill dispatch).
* ``kernels.dispatch.<entry>.<kernels|ref>`` — per-entry-point dispatch
  tallies mirrored from `repro.kernels.ops` at snapshot time. These
  count *Python-level* calls: once per jit trace for traced callers,
  once per call for eager ones — the path tag records which backend the
  trace baked in (wall time for the fused serving dispatches lives in
  the trace spans, where it can be measured honestly).
* ``quality.*`` — rotation-quality probes (int4 path, sampled every K
  decode dispatches): ``quality.<stat>`` pooled histograms and
  ``quality.layer<NN>.<stat>`` per-layer latest-value gauges for
  ``l1_imbalance_pre/post`` (max/mean blockwise ℓ1 mass, the paper's
  Theorem quantity), ``sat_rate`` (int4 codes pinned at the grid ends),
  and ``kurtosis_pre/post``; plus the ``quality.probe_dispatches``
  counter.

Snapshots are versioned dicts (`MetricsRegistry.snapshot()`), mergeable
across processes (`merge`: counters add, histogram buckets add) for the
multi-host roll-up. Traces are Chrome Trace Event Format JSON that opens
directly in Perfetto (`Tracer.save`). `python -m
repro.serve.telemetry.check` validates both artifact kinds in CI.
"""
from .metrics import (SCHEMA_VERSION, Counter, Gauge, Histogram,
                      MetricsRegistry)
from .quality import PROBE_STATS, QualityProbes, activation_probe_stats
from .schema import validate_snapshot
from .trace import PID_ENGINE, PID_REQUESTS, Tracer, validate_trace

__doc__ = __doc__.format(ver=SCHEMA_VERSION)

__all__ = [
    "SCHEMA_VERSION", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Tracer", "validate_trace", "PID_ENGINE", "PID_REQUESTS",
    "QualityProbes", "activation_probe_stats", "PROBE_STATS",
    "validate_snapshot",
]
