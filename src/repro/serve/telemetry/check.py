"""Artifact validator CLI — the CI smoke job's telemetry gate.

    python -m repro.serve.telemetry.check m.json [--trace t.json]

Loads a metrics snapshot written by `launch/serve.py --metrics-json` and
validates it against the engine metric taxonomy (`schema.py`): current
schema version, every required metric present, no unknown names, bucket
counts consistent. With `--trace`, additionally validates the Chrome
Trace JSON (`trace.validate_trace`): required keys per phase, B/E
nesting, non-negative durations. Exits non-zero with the full problem
list on any violation, so a telemetry regression fails the smoke job
instead of silently shipping a partial snapshot.
"""
from __future__ import annotations

import argparse
import json
import sys

from .schema import validate_snapshot
from .trace import validate_trace


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="validate serve telemetry artifacts")
    ap.add_argument("metrics", help="metrics snapshot JSON "
                    "(from launch/serve.py --metrics-json)")
    ap.add_argument("--trace", default=None,
                    help="Chrome Trace JSON (from --trace) to validate too")
    args = ap.parse_args(argv)

    with open(args.metrics) as f:
        snap = json.load(f)
    try:
        validate_snapshot(snap)
    except ValueError as e:
        print(f"FAIL {args.metrics}: {e}", file=sys.stderr)
        return 1
    n_named = sum(len(snap.get(s, {}))
                  for s in ("counters", "gauges", "histograms"))
    print(f"ok {args.metrics}: schema v{snap['schema_version']}, "
          f"{n_named} metrics")

    if args.trace:
        with open(args.trace) as f:
            trace = json.load(f)
        try:
            n_events = validate_trace(trace)
        except ValueError as e:
            print(f"FAIL {args.trace}: {e}", file=sys.stderr)
            return 1
        print(f"ok {args.trace}: {n_events} well-formed trace events")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
