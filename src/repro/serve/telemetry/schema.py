"""The engine metric taxonomy: canonical names + snapshot validation.

Every metric `ServeEngine` emits is declared here, split by instrument
kind. `validate_snapshot` enforces the contract in both directions — a
snapshot must contain every required name (a silently-dropped metric is
a telemetry regression) and must not contain names the taxonomy doesn't
know (a typo'd or ad-hoc metric never lands in the recorded history).
Conditionally-emitted families (register-slot metrics for SSM/hybrid
models, per-entry kernel dispatch tallies, quality-probe stats) are
matched by pattern and may be absent.

The CI smoke job runs a real `--reduced` serve with `--metrics-json` and
fails on any violation; `launch/serve.py` validates before writing, so a
bad snapshot can never be produced in the first place.
"""
from __future__ import annotations

import re

from .metrics import SCHEMA_VERSION

# -- always emitted by the engine --------------------------------------

REQUIRED_COUNTERS = (
    "engine.steps",
    "engine.prefill_tokens",
    "engine.decode_tokens",
    "engine.generated_tokens",
    "engine.pages_walked",
    "engine.pages_walked_dense",
    "engine.requests.submitted",
    "engine.requests.admitted",
    "engine.requests.finished",
    "engine.requests.stop_hits",
    "engine.requests.cancelled",
    "engine.requests.expired",
    "engine.requests.failed",
    "engine.preemptions",
    "engine.replayed_prefill_tokens",
    "engine.dispatch.faults",
    "engine.admission.blocked",
    "engine.prefix.hits",
    "engine.prefix.misses",
    "engine.prefix.hit_tokens",
    "engine.prefix.cow_copies",
    "engine.prefix.inserted_pages",
    "engine.prefix.evicted_pages",
    "engine.swap.out",
    "engine.swap.in",
    "engine.swap.bytes",
    "engine.swap.retries",
    "engine.swap.fallbacks",
    "engine.requests.poisoned",
    "engine.stream.callback_errors",
)

REQUIRED_GAUGES = (
    "engine.pages.capacity",
    "engine.pages.in_use",
    "engine.pages.peak_in_use",
    "engine.pages.utilization",
    "engine.pages.utilization_peak",
    "engine.pages.reserved",
    "engine.pages.scrubbed",
    "engine.queue.depth",
    "engine.batch.decoding",
    "engine.batch.prefilling",
    "engine.pages.shared",
    "engine.prefix.tree_pages",
    "engine.prefix.tree_nodes",
    # host swap tier: zeros when no pool is attached (always emitted so
    # the snapshot shape is policy-independent)
    "engine.swap.host_pages",
    "engine.swap.host_pages_capacity",
    "engine.swap.host_bytes",
    "engine.swap.host_budget_bytes",
)

REQUIRED_HISTOGRAMS = (
    "engine.step.wall_s",
    "engine.step.budget_utilization",
    "engine.decode.batch_occupancy",
    "engine.decode.token_latency_s",
    "engine.admission.wait_s",
    "engine.request.e2e_s",
    "engine.prefill.chunk_tokens",
)

# -- emitted only when the config/run warrants them ---------------------

OPTIONAL_PATTERNS = (
    # register-slot pools exist only for ssm/hybrid state specs
    re.compile(r"^engine\.register_slots\."
               r"(capacity|in_use|peak_in_use|scrubbed)$"),
    # one tally per kernels entry point × dispatch path
    re.compile(r"^kernels\.dispatch\.[a-z0-9_]+\.(kernels|ref)$"),
    # quality probes: pooled histograms + per-layer latest-value gauges
    re.compile(r"^quality\.probe_dispatches$"),
    re.compile(r"^quality\.(layer\d+\.)?"
               r"(l1_imbalance_(pre|post)|sat_rate|kurtosis_(pre|post))$"),
)

_HIST_KEYS = ("base", "growth", "n_buckets", "counts", "count", "sum",
              "min", "max", "p50", "p95", "p99")


def _known(name: str, required: tuple) -> bool:
    return name in required or any(p.match(name) for p in OPTIONAL_PATTERNS)


def validate_snapshot(snap: dict) -> None:
    """Raise ValueError unless `snap` is a schema-valid engine metrics
    snapshot: current schema version, all required metric names present
    in the right instrument section, no unknown names, and well-formed
    histogram payloads."""
    if not isinstance(snap, dict):
        raise ValueError("snapshot must be a dict")
    ver = snap.get("schema_version")
    if ver != SCHEMA_VERSION:
        raise ValueError(f"snapshot schema_version {ver!r} != supported "
                         f"{SCHEMA_VERSION}")
    problems = []
    for section, required in (("counters", REQUIRED_COUNTERS),
                              ("gauges", REQUIRED_GAUGES),
                              ("histograms", REQUIRED_HISTOGRAMS)):
        got = snap.get(section)
        if not isinstance(got, dict):
            problems.append(f"missing section {section!r}")
            continue
        for name in required:
            if name not in got:
                problems.append(f"missing {section[:-1]} {name!r}")
        for name in got:
            if not _known(name, required):
                problems.append(f"unknown {section[:-1]} {name!r}")
    for name, h in (snap.get("histograms") or {}).items():
        if not isinstance(h, dict):
            problems.append(f"histogram {name!r} is not a dict")
            continue
        missing = [k for k in _HIST_KEYS if k not in h]
        if missing:
            problems.append(f"histogram {name!r} missing {missing}")
        elif len(h["counts"]) != h["n_buckets"] \
                or sum(h["counts"]) != h["count"]:
            problems.append(f"histogram {name!r} bucket counts are "
                            "inconsistent with its total count")
    if problems:
        raise ValueError("invalid metrics snapshot:\n  "
                         + "\n  ".join(problems))
