"""Process-local metrics: counters, gauges, exponential-bucket histograms.

Stdlib-only by design — the registry must be importable from the
scheduler's hot loop, from the benches, and (eventually) from a per-host
telemetry shard without dragging jax into the accounting path. All state
is plain Python numbers; nothing here ever touches a device buffer.

Three instrument kinds (see the package docstring for the taxonomy):

* `Counter` — monotonically increasing total (tokens served, pages
  walked). `inc(n)` only; a benchmark that needs a fresh window calls
  `MetricsRegistry.reset()` (or `ServeEngine.reset_metrics()`), never
  decrements.
* `Gauge` — last-observed level (pages in use, queue depth). `set(v)`.
* `Histogram` — exponential buckets `[0, base), [base, base·g), …` with
  the final bucket open-ended. Quantiles (p50/p95/p99) are estimated by
  linear interpolation inside the bucket holding the target rank and
  clamped to the observed min/max, so the estimate is always within one
  bucket-growth factor of the nearest-rank sample statistic — and two
  histograms with the same bucket config can be `merge()`d exactly
  (bucket counts add), which is what the future multi-host case needs:
  per-host registries merge into one fleet view without re-observing.

`snapshot()` emits a plain-dict view stamped with `SCHEMA_VERSION`; the
schema module validates metric names against the engine taxonomy and the
serve bench refuses to append a history row whose schema version
regressed.
"""
from __future__ import annotations

import math

# v2: robustness taxonomy — preemption/cancel/expiry/failure counters,
# replayed prefill tokens, dispatch-fault tally, live/peak utilization
# v3: prefix-sharing taxonomy — radix-cache hit/miss/hit-token/COW/
# insert/evict counters, tree-size and shared-page gauges
# v4: tiered-paging taxonomy — host-swap traffic counters
# (out/in/bytes/retries/fallbacks) + host-tier occupancy gauges, plus
# the poisoned-request and stream-callback-error degradation counters
SCHEMA_VERSION = 4


class Counter:
    """Monotonic total. `value` is plain attribute access so callers that
    mirror an externally-maintained monotonic count (e.g. the kernel
    dispatch tallies) can assign it directly at snapshot time."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int | float = 1):
        if n < 0:
            raise ValueError("counters only increase; use reset() for a "
                             "fresh measurement window")
        self.value += n


class Gauge:
    """Last-observed level."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        self.value = v


class Histogram:
    """Exponential-bucket histogram over non-negative samples.

    Bucket 0 holds `[0, base)`, bucket i holds
    `[base·growth^(i-1), base·growth^i)`, and the last bucket is
    open-ended. The defaults (1 µs base, ×2 growth, 40 buckets) cover
    sub-microsecond dispatch overheads through multi-hour walls, which
    is every latency this engine records; dimensionless ratios
    (occupancy, utilization) ride the same buckets — only relative
    resolution matters for a quantile estimate.
    """

    __slots__ = ("base", "growth", "n_buckets", "counts", "count", "sum",
                 "min", "max")

    def __init__(self, base: float = 1e-6, growth: float = 2.0,
                 n_buckets: int = 40):
        if base <= 0 or growth <= 1 or n_buckets < 2:
            raise ValueError("need base > 0, growth > 1, n_buckets >= 2")
        self.base = base
        self.growth = growth
        self.n_buckets = n_buckets
        self.counts = [0] * n_buckets
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    # -- bucket geometry ------------------------------------------------

    def bucket_index(self, v: float) -> int:
        if v < self.base:
            return 0
        i = 1 + int(math.floor(math.log(v / self.base) / math.log(self.growth)))
        # float log can land a boundary value one bucket low/high; nudge
        # so boundaries classify exactly: bucket i starts at lower(i)
        while i < self.n_buckets - 1 and v >= self.lower(i + 1):
            i += 1
        while i > 1 and v < self.lower(i):
            i -= 1
        return min(i, self.n_buckets - 1)

    def lower(self, i: int) -> float:
        """Inclusive lower bound of bucket `i` (0 for bucket 0)."""
        return 0.0 if i == 0 else self.base * self.growth ** (i - 1)

    def upper(self, i: int) -> float:
        """Exclusive upper bound (inf for the open-ended last bucket)."""
        return math.inf if i >= self.n_buckets - 1 \
            else self.base * self.growth ** i

    # -- recording ------------------------------------------------------

    def observe(self, v: float):
        if v < 0:
            raise ValueError(f"histogram samples must be >= 0, got {v}")
        self.counts[self.bucket_index(v)] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def merge(self, other: "Histogram"):
        """Accumulate `other` into self (exact: bucket counts add). Both
        sides must share the bucket config — the mergeability contract
        for combining per-host registries."""
        if (self.base, self.growth, self.n_buckets) != \
                (other.base, other.growth, other.n_buckets):
            raise ValueError("cannot merge histograms with different "
                             "bucket configs")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    # -- quantiles ------------------------------------------------------

    def quantile(self, q: float) -> float:
        """Nearest-rank quantile estimated from bucket counts: linear
        interpolation inside the bucket holding rank ceil(q·count),
        clamped to the observed min/max. Within one growth factor of the
        exact sample statistic by construction."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return math.nan
        target = max(1, math.ceil(q * self.count))
        cum = 0
        for i, c in enumerate(self.counts):
            if cum + c >= target:
                lo = self.lower(i)
                hi = self.max if math.isinf(self.upper(i)) else self.upper(i)
                frac = (target - cum) / c
                est = lo + frac * (hi - lo)
                return min(max(est, self.min), self.max)
            cum += c
        return self.max      # unreachable unless counts were mutated

    def to_dict(self) -> dict:
        return {
            "base": self.base,
            "growth": self.growth,
            "n_buckets": self.n_buckets,
            "counts": list(self.counts),
            "count": self.count,
            "sum": self.sum,
            "min": None if math.isinf(self.min) else self.min,
            "max": None if math.isinf(self.max) else self.max,
            "p50": None if self.count == 0 else self.quantile(0.50),
            "p95": None if self.count == 0 else self.quantile(0.95),
            "p99": None if self.count == 0 else self.quantile(0.99),
        }


class MetricsRegistry:
    """Process-local, name-keyed instrument store.

    Instruments are created on first access (`counter(name)` etc.) and
    keep their identity for the registry's lifetime, so hot-loop callers
    can hold the instrument object instead of re-resolving the name.
    `snapshot()` is the only export surface; `merge()` combines two
    registries (counters add, gauges keep the other's latest, histograms
    add bucket counts) for the multi-host roll-up.
    """

    def __init__(self):
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str, **kw) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(**kw)
        return h

    def reset(self):
        """Zero every registered instrument in place (names survive, so
        held instrument references stay valid) — the measurement-window
        boundary the benches and `ServeEngine.reset_metrics()` use."""
        for c in self.counters.values():
            c.value = 0
        for g in self.gauges.values():
            g.value = 0.0
        for h in self.histograms.values():
            h.counts = [0] * h.n_buckets
            h.count = 0
            h.sum = 0.0
            h.min = math.inf
            h.max = -math.inf

    def merge(self, other: "MetricsRegistry"):
        """Fold `other` into self: counters add, histograms add bucket
        counts, gauges take `other`'s value (the merge direction is
        "newer shard wins" for levels)."""
        for name, c in other.counters.items():
            self.counter(name).value += c.value
        for name, g in other.gauges.items():
            self.gauge(name).value = g.value
        for name, h in other.histograms.items():
            mine = self.histograms.get(name)
            if mine is None:
                mine = self.histograms[name] = Histogram(
                    base=h.base, growth=h.growth, n_buckets=h.n_buckets)
            mine.merge(h)

    def snapshot(self) -> dict:
        """Versioned plain-dict view (json-serializable)."""
        return {
            "schema_version": SCHEMA_VERSION,
            "counters": {k: v.value for k, v in sorted(self.counters.items())},
            "gauges": {k: v.value for k, v in sorted(self.gauges.items())},
            "histograms": {k: v.to_dict()
                           for k, v in sorted(self.histograms.items())},
        }
