"""Rotation-quality probes on served activations (MixQuant §3 quantities).

The paper's central claim is that permutation + block rotation equalizes
per-block ℓ1 mass, which controls the Prop-3.2 quantization-error bound;
DFRot's analysis predicts the interesting failure mode — massive
activations that survive rotation and saturate the int4 grid — shows up
on *real* traffic, not calibration data. These probes measure exactly
that, on the serving path, per layer:

* `l1_imbalance_pre` / `l1_imbalance_post` — max/mean blockwise ℓ1 mass
  of the activation entering the fused rotate+quantize step, before and
  after the online block-Hadamard rotation (1.0 = perfectly balanced;
  the rotation should pull this toward 1).
* `sat_rate` — fraction of int4 activation codes pinned at either end
  of the asymmetric grid (0 or 2^bits−1): code-point waste / clipping
  pressure from surviving outliers.
* `kurtosis_pre` / `kurtosis_post` — excess-free Pearson kurtosis of the
  same activation (3.0 = Gaussian). Rotations drive activations toward
  Gaussian; a post-rotation kurtosis well above 3 is the DFRot
  massive-activation signature.

Bit-path neutrality: `activation_probe_stats` wraps every input in
`jax.lax.optimization_barrier` before computing, so the probe math is a
side computation XLA cannot fuse into (and thereby re-round) the serving
arithmetic — with probes on, greedy tokens stay bit-identical to probes
off (regression-tested). Overhead stays bounded because the scheduler
samples: only every `every_k`-th decode dispatch runs the probe variant
of the forward.

Stats land in the shared `MetricsRegistry` as `quality.<stat>`
histograms (one observation per layer per probed dispatch) plus
`quality.layer<NN>.<stat>` gauges holding each layer's latest value.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .metrics import MetricsRegistry

PROBE_STATS = ("l1_imbalance_pre", "l1_imbalance_post", "sat_rate",
               "kurtosis_pre", "kurtosis_post")


def _block_l1_imbalance(x: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """max/mean of per-block ℓ1 mass, blocks along the channel axis,
    mass pooled over every token in the chunk — the Theorem-driven
    balance quantity (1.0 = equalized)."""
    d = x.shape[-1]
    mass = jnp.sum(jnp.abs(x).reshape(-1, d // block_size, block_size),
                   axis=(0, 2))
    return jnp.max(mass) / jnp.maximum(jnp.mean(mass), 1e-12)


def _kurtosis(x: jnp.ndarray) -> jnp.ndarray:
    """Pearson kurtosis pooled over all elements (3.0 = Gaussian)."""
    x = x.reshape(-1)
    mu = jnp.mean(x)
    var = jnp.maximum(jnp.mean(jnp.square(x - mu)), 1e-24)
    return jnp.mean(jnp.square(jnp.square(x - mu))) / jnp.square(var)


def activation_probe_stats(pre: jnp.ndarray, post: jnp.ndarray,
                           codes: jnp.ndarray, *, bits: int,
                           block_size: int) -> dict[str, jnp.ndarray]:
    """Per-layer probe scalars from one fused rotate+quantize site.

    `pre` is the activation entering the rotation, `post` the rotated
    activation, `codes` the asymmetric integer codes the main path
    actually dispatched (range [0, 2^bits−1]). Inputs are barriered so
    this side computation cannot perturb serving arithmetic.
    """
    pre = jax.lax.optimization_barrier(pre.astype(jnp.float32))
    post = jax.lax.optimization_barrier(post.astype(jnp.float32))
    codes = jax.lax.optimization_barrier(codes)
    levels = 2 ** bits - 1
    return {
        "l1_imbalance_pre": _block_l1_imbalance(pre, block_size),
        "l1_imbalance_post": _block_l1_imbalance(post, block_size),
        "sat_rate": jnp.mean(((codes == 0) | (codes == levels))
                             .astype(jnp.float32)),
        "kurtosis_pre": _kurtosis(pre),
        "kurtosis_post": _kurtosis(post),
    }


class QualityProbes:
    """Sampling policy + registry sink for the activation probes.

    Construct with the sampling period and hand to
    `ServeEngine(quality_probes=...)`; the engine binds its registry and
    asks `should_probe()` once per decode dispatch — every `every_k`-th
    one (the first included) runs the probe variant of the fused
    forward, whose per-layer stats arrive at `record()` as host arrays.
    """

    def __init__(self, every_k: int = 8):
        if every_k < 1:
            raise ValueError("every_k must be >= 1")
        self.every_k = every_k
        self._registry: MetricsRegistry | None = None
        self._dispatches = 0

    def bind(self, registry: MetricsRegistry):
        self._registry = registry

    def reset(self):
        self._dispatches = 0

    def should_probe(self) -> bool:
        n = self._dispatches
        self._dispatches += 1
        return n % self.every_k == 0

    def record(self, stats: dict[str, "jnp.ndarray"]):
        """`stats`: name → [n_layers] array (the scan-stacked per-layer
        scalars). Each layer's value feeds the pooled histogram and its
        own latest-value gauge."""
        if self._registry is None:
            raise RuntimeError("QualityProbes.record before bind()")
        reg = self._registry
        reg.counter("quality.probe_dispatches").inc()
        for name, arr in stats.items():
            vals = np.asarray(arr, np.float64).reshape(-1)
            hist = reg.histogram(f"quality.{name}")
            for layer, v in enumerate(vals):
                v = float(max(v, 0.0))
                hist.observe(v)
                reg.gauge(f"quality.layer{layer:02d}.{name}").set(v)
