"""Request/dispatch tracing in Chrome Trace Event Format (Perfetto-ready).

`Tracer` records two families of events, stdlib-only and append-only so
the hot loop pays one list append per event:

* **Request lifecycle** (pid `PID_REQUESTS`, tid = request id): a
  `request` span wrapping the whole lifetime, with nested `queued`
  (submit → admission), `prefill` (admission → prompt fully cached) and
  `decode` (first token → finish) spans as B/E pairs, plus instant
  events for page/slot allocations. One row per request in the Perfetto
  track view.
* **Engine dispatches** (pid `PID_ENGINE`, tid 0): each fused
  decode/prefill device dispatch as a complete ("X") event. The duration
  is wall time measured around the dispatch with
  `jax.block_until_ready` on its outputs (the *scheduler* blocks, this
  module never imports jax) — so with tracing on, per-dispatch device
  time is real, at the cost of serializing host/device overlap. Tracing
  is therefore off by default and must stay bit-path-neutral: it may
  only ever add host-side timing/blocking, never change dispatch
  shapes, argument values, or PRNG key consumption (regression-tested
  by the engine parity tests).

Timestamps are microseconds relative to tracer construction
(`time.perf_counter_ns`-derived, monotonic). `save()` writes the
standard `{"traceEvents": [...]}` JSON object that chrome://tracing and
https://ui.perfetto.dev open directly.

`validate_trace` is the well-formedness checker the tests and the CI
smoke job share: every event carries the required keys for its phase,
B/E pairs nest per (pid, tid) with non-negative span lengths, and "X"
durations are non-negative.
"""
from __future__ import annotations

import contextlib
import json
import time

PID_ENGINE = 1
PID_REQUESTS = 2

_PROCESS_NAMES = {PID_ENGINE: "engine", PID_REQUESTS: "requests"}


class Tracer:
    def __init__(self):
        self._t0 = time.perf_counter_ns()
        self.events: list[dict] = []
        for pid, name in _PROCESS_NAMES.items():
            self.events.append({"name": "process_name", "ph": "M",
                                "pid": pid, "tid": 0,
                                "args": {"name": name}})

    def ts(self) -> float:
        """Microseconds since tracer construction."""
        return (time.perf_counter_ns() - self._t0) / 1e3

    def begin(self, name: str, *, pid: int = PID_ENGINE, tid: int = 0,
              args: dict | None = None):
        ev = {"name": name, "ph": "B", "ts": self.ts(), "pid": pid,
              "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def end(self, name: str, *, pid: int = PID_ENGINE, tid: int = 0,
            args: dict | None = None):
        ev = {"name": name, "ph": "E", "ts": self.ts(), "pid": pid,
              "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, *, pid: int = PID_ENGINE, tid: int = 0,
                args: dict | None = None):
        ev = {"name": name, "ph": "i", "ts": self.ts(), "s": "t",
              "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def complete(self, name: str, ts: float, dur: float, *,
                 pid: int = PID_ENGINE, tid: int = 0,
                 args: dict | None = None):
        """An "X" event: `ts`/`dur` in µs on this tracer's clock."""
        ev = {"name": name, "ph": "X", "ts": ts, "dur": max(dur, 0.0),
              "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    @contextlib.contextmanager
    def span(self, name: str, *, pid: int = PID_ENGINE, tid: int = 0,
             args: dict | None = None):
        self.begin(name, pid=pid, tid=tid, args=args)
        try:
            yield
        finally:
            self.end(name, pid=pid, tid=tid)

    def to_dict(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)


def validate_trace(obj: dict) -> int:
    """Raise ValueError unless `obj` is well-formed Chrome Trace JSON:
    a `traceEvents` list whose events carry the keys their phase
    requires, with non-negative "X" durations and B/E pairs that nest
    properly per (pid, tid) track (matching names, end ts >= begin ts).
    Returns the number of events checked."""
    if not isinstance(obj, dict) or not isinstance(
            obj.get("traceEvents"), list):
        raise ValueError("trace must be an object with a traceEvents list")
    stacks: dict[tuple, list] = {}
    for n, ev in enumerate(obj["traceEvents"]):
        if not isinstance(ev, dict):
            raise ValueError(f"event {n} is not an object")
        ph = ev.get("ph")
        for key in ("name", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"event {n} ({ph!r}) missing {key!r}")
        if ph == "M":
            continue
        if ph not in ("B", "E", "X", "i"):
            raise ValueError(f"event {n} has unknown phase {ph!r}")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {n} has invalid ts {ts!r}")
        track = (ev["pid"], ev["tid"])
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {n} ('X') has invalid dur {dur!r}")
        elif ph == "B":
            stacks.setdefault(track, []).append((ev["name"], ts, n))
        elif ph == "E":
            stack = stacks.get(track) or []
            if not stack:
                raise ValueError(
                    f"event {n}: 'E' {ev['name']!r} on track {track} "
                    "without an open 'B'")
            bname, bts, bn = stack.pop()
            if bname != ev["name"]:
                raise ValueError(
                    f"event {n}: 'E' {ev['name']!r} closes 'B' {bname!r} "
                    f"(event {bn}) — spans must nest")
            if ts < bts:
                raise ValueError(
                    f"event {n}: span {ev['name']!r} ends at {ts} before "
                    f"it begins at {bts}")
    open_spans = [(t, s) for t, st in stacks.items() for s in st]
    if open_spans:
        raise ValueError(f"unclosed 'B' spans at end of trace: {open_spans}")
    return len(obj["traceEvents"])
