"""Serving steps: prefill + decode, greedy/temperature sampling, and a
continuous-batching scheduler for the example server.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@functools.partial(jax.jit, donate_argnums=(0,))
def _zero_slot(cache: Params, slot: jnp.ndarray) -> Params:
    """Zero one slot's KV range across every cache leaf (one fused
    dispatch; `slot` is traced so all slots share a single compile; the
    cache is donated so readmission never copies the full KV region)."""
    return jax.tree.map(lambda a: a.at[:, slot].set(0), cache)


def make_prefill_step(model) -> Callable:
    def prefill_step(params: Params, batch: Params, cache: Params):
        logits, cache = model.prefill(params, batch, cache)
        return logits, cache

    return prefill_step


def make_decode_step(model, *, temperature: float = 0.0) -> Callable:
    def decode_step(params: Params, tokens: jnp.ndarray, cache: Params,
                    index: jnp.ndarray, rng: jax.Array | None = None):
        logits, cache = model.decode_step(params, tokens, cache, index)
        if temperature > 0 and rng is not None:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt[:, None].astype(jnp.int32), logits, cache

    return decode_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    generated: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new


class BatchScheduler:
    """Continuous batching (per-token admission, vLLM-style fixed slots).

    All active slots advance one token per `step()`; a slot still consuming
    its prompt feeds the next prompt token, a generating slot feeds its last
    sampled token. Per-slot cache indices (vector `cache_index` support in
    the attention layer) keep every sequence's KV writes independent, so new
    requests are admitted mid-flight without disturbing running ones.

    Attention-cache models only (SSM/hybrid decode is lockstep-batched via
    `make_decode_step` directly — their state has no position index).
    """

    def __init__(self, model, params, *, slots: int, max_len: int,
                 temperature: float = 0.0, cache_dtype=jnp.float32,
                 seed: int = 0):
        if model.cfg.family in ("ssm", "hybrid"):
            raise ValueError("per-slot scheduler requires attention caches")
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}
        self.prompt_ptr: dict[int, int] = {}
        self.pos = [0] * slots
        self.next_feed = [0] * slots
        self.cache = model.init_cache(slots, max_len, dtype=cache_dtype)
        self._rng = jax.random.PRNGKey(seed)
        self._decode = jax.jit(make_decode_step(model,
                                                temperature=temperature))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.slots):
            if slot in self.active or not self.queue:
                continue
            req = self.queue.pop(0)
            if self.pos[slot] > 0:
                # explicit slot-reuse invalidation: zero the freed slot's
                # KV range rather than relying on the per-slot causal mask
                # to hide every stale row of the previous occupant
                self.cache = _zero_slot(self.cache,
                                        jnp.asarray(slot, jnp.int32))
            self.active[slot] = req
            self.prompt_ptr[slot] = 0
            self.pos[slot] = 0
            self.next_feed[slot] = req.prompt[0]

    def step(self) -> list[Request]:
        """Advance every active slot one token; returns finished requests."""
        self._admit()
        if not self.active:
            return []
        tokens = jnp.asarray([[self.next_feed[s]] for s in range(self.slots)],
                             jnp.int32)
        idx = jnp.asarray([self.pos[s] for s in range(self.slots)], jnp.int32)
        rng = None
        if self.temperature > 0:
            # per-step PRNG key: without it `make_decode_step` silently
            # degrades temperature sampling to argmax
            self._rng, rng = jax.random.split(self._rng)
        nxt, _, self.cache = self._decode(self.params, tokens, self.cache,
                                          idx, rng)

        finished = []
        for slot, req in list(self.active.items()):
            self.pos[slot] += 1
            ptr = self.prompt_ptr[slot]
            if ptr + 1 < len(req.prompt):
                # still prefilling: feed the next prompt token
                self.prompt_ptr[slot] = ptr + 1
                self.next_feed[slot] = req.prompt[ptr + 1]
                continue
            tok = int(nxt[slot, 0])
            req.generated.append(tok)
            self.next_feed[slot] = tok
            if req.done or self.pos[slot] >= self.max_len - 1:
                finished.append(req)
                del self.active[slot]
                self.prompt_ptr.pop(slot, None)
        return finished

    def run(self) -> list[Request]:
        done = []
        while self.queue or self.active:
            done.extend(self.step())
        return done
