"""True-integer W4A4 serving path for dense-family archs.

Unlike the fake-quant evaluation path (which stores dequantized bf16
weights), this module *packs* every projection to int4 (two nibbles/byte,
per-output-channel scale) and executes decode with int8 integer arithmetic:

    per projection:  x → per-token asym int4 codes (+scale,+zero)
                     q_a @ q_w int8·int8→int32 on the MXU
                     float epilogue  s_a·s_w·(acc + z_a·colsum)

Every online op runs through the backend dispatch in `repro.kernels.ops` —
never `kernels.ref` directly — so serving gets the Pallas kernels on TPU
(Mosaic), interpret mode elsewhere, and the jnp references under
`use_kernels(False)` (dry-run/roofline). The online block-Hadamard at R̃₃
runs fused with the quantizer (`ops.hadamard_quant`), and `decode_step` /
`prefill` are jit'd end-to-end around the kernel calls (one compiled
function per kernels-enabled state). Weight HBM traffic drops 4× vs bf16
and activation traffic 2×, which is what moves the memory-roofline term in
§Perf.

Dense/VLM decoder geometry only (the paper's serving target). The KV cache
is bf16 by default; `kv_bits ∈ {4, 8}` switches to an integer cache with
asymmetric per-(position, head) scale+zero pairs (KIVI-style), with K
cached pre-RoPE (the rotation is re-applied after dequant at read time —
RoPE mixes each outlier channel across a position-dependent pair of
channels, which inflates the quantization range and wastes code points).

Under the paged serving engine `forward_chunk` additionally takes the
per-sequence block tables: new KV rows (codes + scale/zero for integer
caches) are scattered straight into their pool pages and attention walks
the table through `ops.paged_attention`, which dequantizes and re-rotates
K inside the kernel — the same arithmetic as the dense read path, minus
the slab.

`forward_chunk(..., probe=True)` compiles a probe variant that
additionally returns per-layer rotation-quality stats from the fused
rotate+quantize site (the R̃₃ → W_down path): blockwise ℓ1 mass
imbalance before/after the online rotation, int4 code saturation rate,
and pre/post-rotation kurtosis (`serve.telemetry.quality`). The probe
math reads barrier-isolated copies of the main path's values, so the
serving arithmetic — and hence every sampled token — is bit-identical
with probes on or off; the engine samples it every K decode dispatches.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.context import shard_act
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models.config import ArchConfig
from repro.serve.telemetry.quality import activation_probe_stats

Params = dict[str, Any]

PROJ_NAMES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


def pack_dense_params(params: Params, cfg: ArchConfig) -> Params:
    """Pack every per-layer projection; keep embeddings/norms/head bf16.

    Uses the shared `kernels.ops.pack_int4_weights` packer (vmapped over
    the layer axis) so the serving grid is identical to the fake-quant
    grid the PTQ pipeline produced.
    """
    L_ = params["layers"]
    out = {
        "embed": params["embed"],
        "final_norm": params["final_norm"],
        "lm_head": params["lm_head"],
        "layers": {
            "attn_norm": L_["attn_norm"],
            "ffn_norm": L_["ffn_norm"],
        },
    }
    packed_attn = {}
    for name in ("wq", "wk", "wv", "wo"):
        packed_attn[name] = jax.vmap(kops.pack_int4_weights)(L_["attn"][name])
    for bias in ("bq", "bk", "bv"):
        if bias in L_["attn"]:
            packed_attn[bias] = L_["attn"][bias]
    out["layers"]["attn"] = packed_attn
    packed_ffn = {}
    for name in ("w_gate", "w_up", "w_down"):
        if name in L_["ffn"]:
            packed_ffn[name] = jax.vmap(kops.pack_int4_weights)(
                L_["ffn"][name])
    out["layers"]["ffn"] = packed_ffn
    return out


def _int_linear(x: jnp.ndarray, packed: Params, *, bits: int = 4):
    """x [..., K] float → int4 quantize per token → integer GEMM → float."""
    codes, s, z = kops.quantize_act(x, bits)
    y = kops.int4_matmul(codes, s, z, packed["packed"], packed["scale"])
    return y.astype(x.dtype)


def _rot_int_linear(h: jnp.ndarray, packed: Params, block_size: int):
    """Online block rotation fused with quantization, then integer GEMM
    (the R̃₃ → Q_A → W_down path of Figure 7). Also returns the activation
    codes so the quality probes can read the saturation the main path
    actually dispatched."""
    codes, s, z = kops.hadamard_quant(h, block_size, bits=4)
    y = kops.int4_matmul(codes, s, z, packed["packed"], packed["scale"])
    return y.astype(h.dtype), codes


class QuantizedDenseLM:
    """Integer-arithmetic decode for dense-family configs.

    Built from a PTQ result: `pack_dense_params(ptq.params, cfg)`. Matches
    the fake-quant model's outputs up to activation-quant rounding ties.
    `decode_step` and `prefill` are jit'd end-to-end; the kernels-enabled
    flag is captured per trace, so toggling `ops.use_kernels` transparently
    switches between the Pallas and reference compiled paths.
    """

    def __init__(self, cfg: ArchConfig, *, block_size: int = 32,
                 kv_bits: int | None = None):
        if cfg.family not in ("dense", "vlm"):
            raise ValueError("integer serving path covers dense archs")
        self.cfg = cfg.validate()
        self.block_size = block_size
        # kv_bits=4 → int4 KV cache with asymmetric per-(position, head)
        # scales: cache HBM traffic drops ~3.6× vs bf16 at head_dim 128
        # (the dominant decode byte stream at 32k context — §Perf cell
        # C3). None → bf16 cache.
        self.kv_bits = kv_bits
        self.attn_spec = L.AttnSpec(
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, causal=True, rope_theta=cfg.rope_theta,
            qkv_bias=cfg.qkv_bias)
        # scale granularity: one (scale, zero) pair per (position, head) —
        # KIVI-style. Sub-head groups (e.g. 8) look finer-grained but pair
        # a head's outlier channel with only 7 small neighbours, so the
        # group range is outlier-set while the code budget stays 8 wide;
        # head-wide asymmetric min/max tracks the fake-quant path strictly
        # better on the outlier-injected serving tests.
        self.kv_group = cfg.head_dim
        self._jit_cache: dict = {}

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        if self.kv_bits is not None:
            kv, dh = self.cfg.n_kv_heads, self.cfg.head_dim
            ng = dh // self.kv_group
            one = {
                "k": jnp.zeros((batch, max_len, kv, dh), jnp.int8),
                "v": jnp.zeros((batch, max_len, kv, dh), jnp.int8),
                "k_scale": jnp.ones((batch, max_len, kv, ng), jnp.float32),
                "v_scale": jnp.ones((batch, max_len, kv, ng), jnp.float32),
                "k_zero": jnp.zeros((batch, max_len, kv, ng), jnp.float32),
                "v_zero": jnp.zeros((batch, max_len, kv, ng), jnp.float32),
            }
        else:
            one = L.init_attention_cache(batch, max_len, self.attn_spec,
                                         dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.cfg.n_layers, *a.shape)), one)

    @staticmethod
    def _write_rows(buf, val, index):
        """Update `buf` [B, L, ...] with `val` [B, S, ...] at fill position
        `index` — a scalar (all rows at the same offset, any S) or a [B]
        vector (per-slot offsets, S == 1: the continuous-batching decode
        case, mirroring the per-slot path in `models.layers.attention`)."""
        if jnp.ndim(index) == 1:
            rows = jnp.arange(buf.shape[0])
            return buf.at[rows, index].set(val[:, 0].astype(buf.dtype))
        return jax.lax.dynamic_update_slice(
            buf, val.astype(buf.dtype), (0, index, 0, 0))

    def _quantize_kv(self, x):
        """Asymmetric per-(position, head) KV quantization → (codes int8,
        scale f32, zero f32). Codes are stored offset by 2^(bits-1) so the
        unsigned range fits the int8 cache buffer at kv_bits=8."""
        bits = self.kv_bits
        levels = 2 ** bits - 1
        off = 2 ** (bits - 1)
        g = self.kv_group
        shp = x.shape
        xg = x.astype(jnp.float32).reshape(*shp[:-1], shp[-1] // g, g)
        mn = jnp.min(xg, -1, keepdims=True)
        mx = jnp.max(xg, -1, keepdims=True)
        # floor keeps zero-range groups from dividing by 0 while leaving
        # the zero-point small enough for exact f32 arithmetic
        s = jnp.maximum((mx - mn) / levels, 1e-6)
        z = jnp.round(mn / s)
        codes = jnp.clip(jnp.round(xg / s) - z, 0, levels)
        return ((codes - off).reshape(shp).astype(jnp.int8),
                s[..., 0].astype(jnp.float32),
                z[..., 0].astype(jnp.float32))

    def _kv_leaves(self, k, v):
        """The (leaf name, value) pairs one KV write must store."""
        if self.kv_bits is None:
            return (("k", k), ("v", v))
        kq, ks, kz = self._quantize_kv(k)
        vq, vs, vz = self._quantize_kv(v)
        return (("k", kq), ("v", vq), ("k_scale", ks), ("v_scale", vs),
                ("k_zero", kz), ("v_zero", vz))

    def _cache_write(self, cache, k, v, index):
        """Write new K/V rows at positions [index, index+S) (bf16, or
        asymmetric integer codes per kv_bits with per-(position, head)
        scale+zero). For integer caches K arrives and is stored PRE-RoPE
        (the rotation is applied after dequantization in `_block`); the
        bf16 cache stores K already rotated."""
        out = dict(cache)
        for name, val in self._kv_leaves(k, v):
            out[name] = self._write_rows(cache[name], val, index)
        return out

    def _paged_cache_write(self, cache, k, v, positions, block_table):
        """Scatter new rows straight into their pages (pool leaves
        [n_pages, page_size, ...]) — the block-table-native counterpart of
        `_cache_write`, same quantization arithmetic."""
        out = dict(cache)
        for name, val in self._kv_leaves(k, v):
            out[name] = L.paged_write_rows(cache[name], val, block_table,
                                           positions)
        return out

    def _cache_read(self, cache):
        """Dequantize the whole cache → (K, V); K is still pre-RoPE."""
        if self.kv_bits is None:
            return cache["k"], cache["v"]
        off = 2 ** (self.kv_bits - 1)
        g = self.kv_group

        def dq(codes, scale, zero):
            shp = codes.shape
            cg = (codes.astype(jnp.float32) + off).reshape(
                *shp[:-1], shp[-1] // g, g)
            return (scale[..., None] * (cg + zero[..., None])).reshape(shp)

        return dq(cache["k"], cache["k_scale"], cache["k_zero"]), \
            dq(cache["v"], cache["v_scale"], cache["v_zero"])

    def _block(self, x, blk, cache, index, block_table=None,
               seq_lengths=None, probe=False):
        cfg = self.cfg
        spec = self.attn_spec
        b, s, d = x.shape
        h_, kv, dh = spec.n_heads, spec.n_kv_heads, spec.head_dim

        hx = L.apply_norm(x, blk["attn_norm"], cfg.norm)
        q = _int_linear(hx, blk["attn"]["wq"])
        k = _int_linear(hx, blk["attn"]["wk"])
        v = _int_linear(hx, blk["attn"]["wv"])
        if spec.qkv_bias:
            q = q + blk["attn"]["bq"]
            k = k + blk["attn"]["bk"]
            v = v + blk["attn"]["bv"]
        q = q.reshape(b, s, h_, dh)
        k = k.reshape(b, s, kv, dh)
        v = v.reshape(b, s, kv, dh)
        # index may be a scalar (lockstep batch / prefill chunk) or [B]
        # (per-slot fill positions from the continuous-batching engine)
        per_slot = jnp.ndim(index) == 1
        if per_slot and s != 1:
            raise ValueError("per-slot cache_index requires q_len == 1")
        base = index[:, None] if per_slot else jnp.reshape(index, (1, 1))
        pos = jnp.broadcast_to(jnp.arange(s)[None, :] + base, (b, s))
        q = L.apply_rope(q, pos, spec.rope_theta)
        if self.kv_bits is None:
            # bf16 cache: rotate only the new rows, store post-RoPE
            k = L.apply_rope(k, pos, spec.rope_theta)
        if block_table is not None:
            # block-table-native: scatter the new rows into their pages and
            # walk the table in the kernel (in-kernel dequant + pre-RoPE K
            # rotation for the integer page formats)
            new_cache = self._paged_cache_write(cache, k, v, pos, block_table)
            attn = kops.paged_attention(
                q, new_cache, block_table, pos, seq_lengths,
                rope_theta=spec.rope_theta if self.kv_bits is not None
                else None,
                kv_bits=self.kv_bits,
                kv_group=self.kv_group if self.kv_bits is not None else None)
            attn = attn.reshape(b, s, h_ * dh).astype(x.dtype)
        else:
            new_cache = self._cache_write(cache, k, v, index)
            k_all, v_all = self._cache_read(new_cache)
            s_k = k_all.shape[1]
            if self.kv_bits is not None:
                # integer cache holds pre-RoPE K: rotate after dequant
                all_pos = jnp.broadcast_to(jnp.arange(s_k)[None], (b, s_k))
                k_all = L.apply_rope(k_all.astype(jnp.float32), all_pos,
                                     spec.rope_theta)
            # causal per-query validity: the query at position p sees keys
            # ≤ p (per-row positions when `index` is per-slot)
            valid = jnp.arange(s_k)[None, None, :] <= pos[:, :, None]
            g = h_ // kv
            qg = q.reshape(b, s, kv, g, dh)
            logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                                k_all.astype(jnp.float32)) / math.sqrt(dh)
            logits = jnp.where(valid[:, None, None, :, :], logits, -1e30)
            probs = jax.nn.softmax(logits, axis=-1)
            attn = jnp.einsum("bkgqs,bskd->bqkgd", probs,
                              v_all.astype(jnp.float32))
            attn = attn.reshape(b, s, h_ * dh).astype(x.dtype)
        x = x + _int_linear(attn, blk["attn"]["wo"])

        hx = L.apply_norm(x, blk["ffn_norm"], cfg.norm)
        if "w_gate" in blk["ffn"]:
            hid = jax.nn.silu(_int_linear(hx, blk["ffn"]["w_gate"])) \
                * _int_linear(hx, blk["ffn"]["w_up"])
        else:
            hid = jax.nn.gelu(_int_linear(hx, blk["ffn"]["w_up"]))
        hid = shard_act(hid, ("batch", "seq", "mlp"))
        down, act_codes = _rot_int_linear(hid, blk["ffn"]["w_down"],
                                          self.block_size)
        x = x + down
        stats = None
        if probe:
            # rotation-quality probe on the paper's fused rotate+quantize
            # site: barrier-isolated reads of the main path's values (the
            # rotated activation is recomputed from a barriered copy — the
            # fused kernel never materializes it), so the probe cannot
            # perturb serving arithmetic
            hid_p = jax.lax.optimization_barrier(hid.astype(jnp.float32))
            post = kops.block_hadamard(hid_p, self.block_size)
            stats = activation_probe_stats(hid_p, post, act_codes, bits=4,
                                           block_size=self.block_size)
        return x, new_cache, stats

    def _forward(self, params: Params, tokens: jnp.ndarray, cache: Params,
                 index: jnp.ndarray, block_table=None, seq_lengths=None,
                 probe=False):
        cfg = self.cfg
        cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
        x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
        x = shard_act(x, ("batch", "seq", "embed"))

        def body(carry, inp):
            blk, c = inp
            x2, nc, stats = self._block(carry, blk, c, index, block_table,
                                        seq_lengths, probe)
            return x2, ((nc, stats) if probe else nc)

        x, ys = jax.lax.scan(body, x, (params["layers"], cache))
        new_cache, stats = ys if probe else (ys, None)
        x = L.apply_norm(x, params["final_norm"], cfg.norm)
        logits = x @ params["lm_head"].astype(x.dtype)
        if probe:
            # stats: dict of [n_layers] arrays (scan-stacked per-layer
            # probe scalars)
            return logits, new_cache, stats
        return logits, new_cache

    def _jitted(self, name, impl, probe=False):
        """jit `impl` once per (entry point, kernels-enabled, probe)
        triple; the kernels flag is re-pinned inside the traced body so
        retraces (new shapes) keep the path they were requested under,
        and the probe variant is a separate executable whose extra
        outputs never touch the non-probe path's jit cache."""
        key = (name, kops.kernels_enabled(), probe)
        fn = self._jit_cache.get(key)
        if fn is None:
            enabled = key[1]

            def wrapped(params, tokens, cache, index, block_table=None,
                        seq_lengths=None):
                with kops.use_kernels(enabled):
                    return impl(params, tokens, cache, index, block_table,
                                seq_lengths, probe)

            fn = self._jit_cache[key] = jax.jit(wrapped)
        return fn

    def forward_chunk(self, params: Params, tokens: jnp.ndarray,
                      cache: Params, index: jnp.ndarray,
                      block_table: jnp.ndarray | None = None,
                      seq_lengths: jnp.ndarray | None = None, *,
                      probe: bool = False):
        """Token chunk [B, S] at fill position `index` → per-position
        logits [B, S, V] + updated cache. S == 1 with a [B] vector index
        is a per-slot continuous-batching decode step; S > 1 with a
        scalar index is one chunk of a chunked prefill (causal within
        the chunk, attending to everything already cached). With
        `block_table` [B, P] the cache is the engine's page pool and
        attention runs block-table-native; `seq_lengths` [B] feed the
        paged kernel's ragged early-exit. `probe=True` additionally
        returns per-layer rotation-quality stats (see module docstring);
        the main outputs are bit-identical either way."""
        return self._jitted("forward", self._forward, probe)(
            params, tokens, cache, jnp.asarray(index, jnp.int32),
            block_table, seq_lengths)

    def decode_step(self, params: Params, tokens: jnp.ndarray,
                    cache: Params, index: jnp.ndarray):
        """One decode step for [B, 1] tokens at fill position `index`
        (scalar, or [B] per-slot fill positions)."""
        logits, new_cache = self.forward_chunk(params, tokens, cache, index)
        return logits[:, 0], new_cache

    def prefill(self, params: Params, tokens: jnp.ndarray, cache: Params):
        """Process a [B, S] prompt from position 0 (causal within the
        block); returns per-position logits [B, S, V] and the filled
        cache — decode then continues at index S."""
        return self._jitted("forward", self._forward)(
            params, tokens, cache, jnp.asarray(0, jnp.int32))
