"""True-integer W4A4 serving path for dense-family archs.

Unlike the fake-quant evaluation path (which stores dequantized bf16
weights), this module *packs* every projection to int4 (two nibbles/byte,
per-output-channel scale) and executes decode with int8 integer arithmetic:

    per projection:  x → per-token asym int4 codes (+scale,+zero)
                     q_a @ q_w int8·int8→int32 on the MXU
                     float epilogue  s_a·s_w·(acc + z_a·colsum)

and the online block-Hadamard at R̃₃ runs fused with the quantizer
(`hadamard_quant`). Weight HBM traffic drops 4× vs bf16 and activation
traffic 2×, which is what moves the memory-roofline term in §Perf.

Dense/VLM decoder geometry only (the paper's serving target); the KV cache
stays bf16 (a further 4× KV win is possible with int4 KV — noted as future
work in DESIGN.md).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.context import shard_act
from repro.kernels import ref as kref
from repro.models import layers as L
from repro.models.config import ArchConfig

Params = dict[str, Any]

PROJ_NAMES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


def pack_linear(w: jnp.ndarray) -> Params:
    """Symmetric per-output-channel int4 pack of [K, N] (absmax scale —
    PTQ pipelines hand us weights already rounded to their grid, so absmax
    is exact on grid points)."""
    scale = jnp.max(jnp.abs(w), axis=0) / 7.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(w / scale[None]), -7, 7).astype(jnp.int8)
    return {"packed": kref.int4_pack(codes),
            "scale": scale.astype(jnp.float32)}


def pack_dense_params(params: Params, cfg: ArchConfig) -> Params:
    """Pack every per-layer projection; keep embeddings/norms/head bf16."""
    L_ = params["layers"]
    out = {
        "embed": params["embed"],
        "final_norm": params["final_norm"],
        "lm_head": params["lm_head"],
        "layers": {
            "attn_norm": L_["attn_norm"],
            "ffn_norm": L_["ffn_norm"],
        },
    }
    packed_attn = {}
    for name in ("wq", "wk", "wv", "wo"):
        w = L_["attn"][name]
        packed = jax.vmap(pack_linear)(w)
        packed_attn[name] = packed
    for bias in ("bq", "bk", "bv"):
        if bias in L_["attn"]:
            packed_attn[bias] = L_["attn"][bias]
    out["layers"]["attn"] = packed_attn
    packed_ffn = {}
    for name in ("w_gate", "w_up", "w_down"):
        if name in L_["ffn"]:
            packed_ffn[name] = jax.vmap(pack_linear)(L_["ffn"][name])
    out["layers"]["ffn"] = packed_ffn
    return out


def _int_linear(x: jnp.ndarray, packed: Params, *, bits: int = 4):
    """x [..., K] float → int4 quantize per token → integer GEMM → float."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    codes, s, z = kref.quantize_act_int_ref(x2, bits)
    y = kref.int4_matmul_ref(codes, s, z, packed["packed"], packed["scale"])
    return y.reshape(*lead, -1).astype(x.dtype)


def _rot_int_linear(h: jnp.ndarray, packed: Params, block_size: int):
    """Online block rotation fused with quantization, then integer GEMM
    (the R̃₃ → Q_A → W_down path of Figure 7)."""
    lead = h.shape[:-1]
    h2 = h.reshape(-1, h.shape[-1])
    codes, s, z = kref.hadamard_quant_ref(h2, block_size, 4)
    y = kref.int4_matmul_ref(codes, s, z, packed["packed"], packed["scale"])
    return y.reshape(*lead, -1).astype(h.dtype)


class QuantizedDenseLM:
    """Integer-arithmetic decode for dense-family configs.

    Built from a PTQ result: `pack_dense_params(ptq.params, cfg)`. Matches
    the fake-quant model's outputs up to activation-quant rounding ties.
    """

    def __init__(self, cfg: ArchConfig, *, block_size: int = 32,
                 kv_bits: int | None = None):
        if cfg.family not in ("dense", "vlm"):
            raise ValueError("integer serving path covers dense archs")
        self.cfg = cfg.validate()
        self.block_size = block_size
        # kv_bits=4 → int4 KV cache with per-(position, head) scales: cache
        # HBM traffic drops ~3.6× vs bf16 (the dominant decode byte stream
        # at 32k context — §Perf cell C3). None → bf16 cache.
        self.kv_bits = kv_bits
        self.attn_spec = L.AttnSpec(
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, causal=True, rope_theta=cfg.rope_theta,
            qkv_bias=cfg.qkv_bias)

    KV_GROUP = 8   # scale granularity along head_dim (KIVI-style groups)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        if self.kv_bits is not None:
            kv, dh = self.cfg.n_kv_heads, self.cfg.head_dim
            ng = dh // self.KV_GROUP
            one = {
                "k": jnp.zeros((batch, max_len, kv, dh), jnp.int8),
                "v": jnp.zeros((batch, max_len, kv, dh), jnp.int8),
                "k_scale": jnp.ones((batch, max_len, kv, ng), jnp.float32),
                "v_scale": jnp.ones((batch, max_len, kv, ng), jnp.float32),
            }
        else:
            one = L.init_attention_cache(batch, max_len, self.attn_spec,
                                         dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.cfg.n_layers, *a.shape)), one)

    def _cache_write(self, cache, k, v, index):
        """Write new K/V at `index` (bf16 or int-quantized per kv_bits with
        per-(position, head, group-of-8) scales)."""
        if self.kv_bits is None:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, index, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, index, 0, 0))
            return {"k": ck, "v": cv}
        qmax = 2 ** (self.kv_bits - 1) - 1
        g = self.KV_GROUP

        def q(x):
            shp = x.shape
            xg = x.reshape(*shp[:-1], shp[-1] // g, g)
            s = jnp.maximum(jnp.max(jnp.abs(xg), -1, keepdims=True),
                            1e-6) / qmax
            codes = jnp.clip(jnp.round(xg / s), -qmax, qmax)
            return (codes.reshape(shp).astype(jnp.int8),
                    s[..., 0].astype(jnp.float32))

        kq, ks = q(k.astype(jnp.float32))
        vq, vs = q(v.astype(jnp.float32))
        out = dict(cache)
        out["k"] = jax.lax.dynamic_update_slice(cache["k"], kq,
                                                (0, index, 0, 0))
        out["v"] = jax.lax.dynamic_update_slice(cache["v"], vq,
                                                (0, index, 0, 0))
        out["k_scale"] = jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                                      (0, index, 0, 0))
        out["v_scale"] = jax.lax.dynamic_update_slice(cache["v_scale"], vs,
                                                      (0, index, 0, 0))
        return out

    def _cache_read(self, cache):
        if self.kv_bits is None:
            return cache["k"], cache["v"]
        g = self.KV_GROUP

        def dq(codes, scale):
            shp = codes.shape
            cg = codes.astype(jnp.float32).reshape(*shp[:-1], shp[-1] // g, g)
            return (cg * scale[..., None]).reshape(shp)

        return dq(cache["k"], cache["k_scale"]), \
            dq(cache["v"], cache["v_scale"])

    def _block(self, x, blk, cache, index):
        cfg = self.cfg
        spec = self.attn_spec
        b, s, d = x.shape
        h_, kv, dh = spec.n_heads, spec.n_kv_heads, spec.head_dim

        hx = L.apply_norm(x, blk["attn_norm"], cfg.norm)
        q = _int_linear(hx, blk["attn"]["wq"])
        k = _int_linear(hx, blk["attn"]["wk"])
        v = _int_linear(hx, blk["attn"]["wv"])
        if spec.qkv_bias:
            q = q + blk["attn"]["bq"]
            k = k + blk["attn"]["bk"]
            v = v + blk["attn"]["bv"]
        q = q.reshape(b, s, h_, dh)
        k = k.reshape(b, s, kv, dh)
        v = v.reshape(b, s, kv, dh)
        pos = jnp.broadcast_to(jnp.arange(s)[None] + index, (b, s))
        q = L.apply_rope(q, pos, spec.rope_theta)
        k = L.apply_rope(k, pos, spec.rope_theta)
        new_cache = self._cache_write(cache, k, v, index)
        k_all, v_all = self._cache_read(new_cache)
        s_k = k_all.shape[1]
        valid = jnp.arange(s_k) <= index
        g = h_ // kv
        qg = q.reshape(b, s, kv, g, dh)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                            k_all.astype(jnp.float32)) / math.sqrt(dh)
        logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("bkgqs,bskd->bqkgd", probs,
                          v_all.astype(jnp.float32))
        attn = attn.reshape(b, s, h_ * dh).astype(x.dtype)
        x = x + _int_linear(attn, blk["attn"]["wo"])

        hx = L.apply_norm(x, blk["ffn_norm"], cfg.norm)
        if "w_gate" in blk["ffn"]:
            hid = jax.nn.silu(_int_linear(hx, blk["ffn"]["w_gate"])) \
                * _int_linear(hx, blk["ffn"]["w_up"])
        else:
            hid = jax.nn.gelu(_int_linear(hx, blk["ffn"]["w_up"]))
        hid = shard_act(hid, ("batch", "seq", "mlp"))
        x = x + _rot_int_linear(hid, blk["ffn"]["w_down"], self.block_size)
        return x, new_cache

    def decode_step(self, params: Params, tokens: jnp.ndarray,
                    cache: Params, index: jnp.ndarray):
        cfg = self.cfg
        cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
        x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
        x = shard_act(x, ("batch", "seq", "embed"))

        def body(carry, inp):
            blk, c = inp
            return self._block(carry, blk, c, index)

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        x = L.apply_norm(x, params["final_norm"], cfg.norm)
        logits = x @ params["lm_head"].astype(x.dtype)
        return logits[:, 0], new_cache
