"""Paged serving state: KV pages + fixed-size register slots, per sequence.

The engine's device-side state is one partitioned pytree per served model,
`{"kv": ..., "register": ...}`, because architectures carry two different
kinds of per-sequence state:

  * **kv** leaves grow with sequence length. They live in a single *page
    pool* per leaf — shape `[n_layers, n_pages, page_size, ...]` — with a
    host-side block table per sequence mapping logical positions to pages.
    Pages are allocated lazily as a sequence grows and freed on completion,
    so pool HBM is shared across sequences of very different lengths (the
    vLLM PagedAttention memory model). Dense/MoE attention caches are pure
    kv; a hybrid's shared-attention cache is its kv part.
  * **register** leaves are fixed-size per sequence — a Mamba2 layer's conv
    tail `[W-1, conv_dim]` and SSD state `[H, N, P]` do not grow with
    context. They live in *slot pools* — `[n_layers, n_slots, ...]` — and a
    sequence is assigned one register slot at admission, carried until
    release. No block table: the slot id indexes axis 1 of every register
    leaf directly. Pure-SSM models are all register; hybrids mix both kinds
    in one state pytree.

The kv data path is block-table-native: the scheduler hands the pool and
the per-sequence block-table rows straight to the backend's
`forward_chunk`, which scatters each new KV row into its page and attends
by walking the table inside `kernels.ops.paged_attention` (one Mosaic
kernel on TPU: the page ids are scalar-prefetched and each page is DMA'd
into VMEM exactly once, with online softmax across the walk). No
contiguous slab is ever materialised. Register leaves are gathered by slot
index at the top of the forward and scattered back once per call.

Both pools are format-agnostic: they are built by the adapter's
`init_state(n_pages, page_size, n_slots)` — the page/slot axis *is* the
batch axis — so the same machinery pages the bf16 cache ({k, v}), the
asymmetric per-(position, head) int8/int4 KV cache (codes *and* their
scale/zero rows), and the SSM conv/SSD slot pools.

This module keeps the *bookkeeping*: the two allocators, block tables and
register-slot maps, and release-time scrubbing. KV pages are
**refcounted** so many sequences — and the radix prefix cache
(`radix.RadixCache`) — can point at the same immutable prefix page:
`alloc()` hands out pages at refcount 1, `incref()` adds a holder, and
`free()` *decrements*, returning a page to the free list only when its
count hits zero (the list of pages that actually dropped to zero is
`free()`'s return value). The refcount/copy-on-write contract is:

  * a page is only ever *written* by a holder that owns it exclusively
    (refcount 1): freshly-allocated pages, or a private copy made by the
    scheduler's copy-on-write dispatch before extending a shared page;
  * shared pages (refcount > 1) are immutable until every holder has
    dropped its reference — so releasing one sharer can never perturb
    the bits another sharer (or the prefix tree) is still reading;
  * **scrub-on-release applies only to exclusively-owned state**: the
    fused `scrub()` dispatch zeroes exactly the pages `free()` reported
    as dropping to refcount 0, plus the released register slot. Zeroing
    a still-referenced page would corrupt live readers; skipping the
    zero on an exclusively-freed one would leak state into its next
    owner (load-bearing for register slots, defence in depth for KV).

Register slots are *excluded* from all sharing: SSM conv/SSD state is a
position-dependent running summary, not an addressable prefix, so a slot
always has exactly one owner and is scrubbed on every release.

The same `release()`/`scrub()` path serves normal completion,
cancellation, and preemption — a preempted victim's shared pages are
simply unpinned (deref'd, never scrubbed) while its exclusive pages are
zeroed and returned; its state is either recomputed later by replaying
the host-known token stream, or parked in the **host swap tier** (below)
and copied back at re-admission. `release(rid, adopted=k)` lets the
prefix tree take over the request's reference on its first `k` pages
instead of dropping them. `alloc()` validates before mutating:
`MemoryError` on exhaustion leaves the free list untouched, which is
what lets the scheduler evict cached prefixes or preempt a victim and
simply retry. Each release scrubs through ONE fused jit dispatch (pages
of every kv leaf + the register slot together, page counts padded to
powers of two to bound the jit variants), tallied as `scrub_state` in
the `kernels.ops` dispatch counts. The legacy `gather_pages` /
`scatter_*_rows` primitives survive purely as the test oracle the paged
kernel is checked against.

**Two-tier residency.** When a `HostSwapPool` is attached (an
engine-configured host-memory budget, `--swap-host-mb`), a KV page has
one of three residencies:

  * **device** — a plain `int` page id in the block table, readable by
    every fused dispatch; the only residency the kernels ever see.
  * **host** — a `HostPageRef` table entry naming a slot of the pool's
    numpy mirror (one buffer per kv leaf, shaped like the device pool
    with the page axis sized to the budget). The device copy was
    scrubbed and returned to the allocator; the bytes live only on host.
  * **in-flight** — a device page id currently inside a swap transfer
    window (`PagedKVCache._inflight`). Scrub and copy-on-write assert
    against touching it, so a transfer can never race state maintenance.

`swap_out(rid)` moves exactly the victim's *exclusively-held* device
pages (refcount 1) to host slots — one fused gather dispatch + one
`device_get`, tallied as `swap_out` — then derefs them so the device
copies scrub and return to the pool. Shared pages (radix tree or sibling
sequences hold references) keep the victim's reference and stay device
resident: a radix-shared page is therefore swapped at most once — in
practice never, because tree-held prefixes are live device state other
sequences still read — and a copy-on-write source is always device
resident, never a `HostPageRef`. `swap_in(rid, alloc_fn)` allocates
fresh device pages first (so `MemoryError` mutates nothing), copies the
host slots back through one `device_put` + fused scatter (`swap_in`),
patches the block-table row in place, and releases the host slots. The
bytes moved per page (`page_bytes`, from the adapter's state-spec
dtypes) are what the scheduler's swap-vs-replay cost rule weighs against
re-prefill tokens — quantized int4/int8 KV pages cost 4-8x less traffic
per page than bf16, which is exactly what tips the rule toward swap.

Page 0 / slot 0 are reserved as scratch: padded batch rows (inactive
slots) and padded block-table entries point at them, so their masked
reads and dead writes can never touch a live sequence's state (which is
also what makes scratch-padded scrub index vectors harmless).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

Params = dict[str, Any]

SCRATCH_PAGE = 0
SCRATCH_SLOT = 0


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold `n_tokens` KV rows."""
    return -(-n_tokens // page_size)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _scrub_impl(state: Params, page_idx: jnp.ndarray, slot: jnp.ndarray,
                *, do_slot: bool) -> Params:
    """One fused dispatch zeroing `page_idx` rows of every kv leaf and —
    when `do_slot` — slot `slot` of every register leaf. `page_idx` may
    be scratch-padded (zeroing the scratch page is a harmless dead
    write); `slot` is scratch when only pages are scrubbed."""
    kv = jax.tree.map(
        lambda a: a.at[:, page_idx].set(jnp.zeros((), a.dtype)),
        state["kv"])
    register = state["register"]
    if do_slot:
        register = jax.tree.map(
            lambda a: a.at[:, slot].set(jnp.zeros((), a.dtype)), register)
    return {"kv": kv, "register": register}


def _cow_impl(state: Params, src: jnp.ndarray, dst: jnp.ndarray) -> Params:
    """Copy page `src` into page `dst` on every kv leaf (one dispatch)."""
    return {"kv": jax.tree.map(
        lambda a: a.at[:, dst].set(
            jax.lax.dynamic_index_in_dim(a, src, axis=1, keepdims=False)),
        state["kv"]), "register": state["register"]}


def _swap_gather_impl(state: Params, page_idx: jnp.ndarray) -> Params:
    """Gather `page_idx` rows of every kv leaf into contiguous blocks —
    the device half of swap-out, fused into one dispatch so a victim's
    whole page set leaves in a single `device_get`. `page_idx` may be
    scratch-padded (the extra rows are sliced off host-side)."""
    return jax.tree.map(lambda a: a[:, page_idx], state["kv"])


def _swap_scatter_impl(state: Params, blocks: Params,
                       page_idx: jnp.ndarray) -> Params:
    """Scatter host blocks back into `page_idx` rows of every kv leaf —
    the device half of swap-in, one fused dispatch over a single
    `device_put`. Pad entries target the scratch page with zero blocks
    (dead writes by the scratch contract)."""
    return {"kv": jax.tree.map(
        lambda a, b: a.at[:, page_idx].set(b.astype(a.dtype)),
        state["kv"], blocks), "register": state["register"]}


class HostPageRef:
    """Block-table entry for a host-resident page: names a slot of the
    `HostSwapPool` mirror instead of a device page id. Kernels never see
    one — `block_table_array` refuses to serialize a table holding any —
    so a sequence with host-resident pages must swap in before dispatch.
    """

    __slots__ = ("slot",)

    def __init__(self, slot: int):
        self.slot = slot

    def __repr__(self):
        return f"HostPageRef({self.slot})"


class HostSwapPool:
    """Host-memory mirror of the device kv page pool — the swap tier.

    One numpy buffer per kv leaf, shaped like the device pool with the
    page axis resized to the budget: `[n_layers, n_slots, page_size,
    ...]`. Capacity is derived from a byte budget and the per-page byte
    cost of the adapter's state spec (quantized page formats shrink it
    4-8x, which is what makes offload cheaper than recompute). Slots are
    a plain free list — host pages are never shared (only exclusively
    held device pages are ever swapped out), so there is no refcounting
    and no scratch slot on this tier.
    """

    def __init__(self, kv_template: Params, budget_bytes: int):
        if budget_bytes < 0:
            raise ValueError("host swap budget must be >= 0 bytes")
        leaves = jax.tree.leaves(kv_template)
        self.page_bytes = sum(
            a.shape[0] * int(np.prod(a.shape[2:], dtype=np.int64))
            * np.dtype(a.dtype).itemsize for a in leaves)
        self.capacity = (int(budget_bytes // self.page_bytes)
                         if self.page_bytes else 0)
        self.buf = jax.tree.map(
            lambda a: np.zeros((a.shape[0], self.capacity) + tuple(a.shape[2:]),
                               np.dtype(a.dtype)), kv_template)
        self._free = list(range(self.capacity - 1, -1, -1))
        self._free_set = set(self._free)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.capacity - len(self._free)

    def take(self, n: int) -> list[int]:
        """Claim `n` host slots (validated before mutating, like
        `PageAllocator.alloc`: `MemoryError` leaves the free list whole)."""
        if n > len(self._free):
            raise MemoryError(f"host swap tier exhausted: need {n}, "
                              f"free {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(out)
        return out

    def store(self, slots: list[int], blocks: Params):
        """Copy gathered page blocks (`[n_layers, len(slots), ...]` per
        leaf, already on host) into the claimed slots."""
        idx = np.asarray(slots, np.int64)
        for buf, b in zip(jax.tree.leaves(self.buf),
                          jax.tree.leaves(blocks)):
            buf[:, idx] = b

    def load(self, slots: list[int]) -> Params:
        """Read the slots back as contiguous blocks (numpy views stacked
        per leaf), ready for one `device_put`."""
        idx = np.asarray(slots, np.int64)
        return jax.tree.map(lambda buf: buf[:, idx], self.buf)

    def release(self, slots: list[int]):
        """Return slots to the free list (validated as a batch first)."""
        batch = set()
        for s in slots:
            if s < 0 or s >= self.capacity or s in self._free_set \
                    or s in batch:
                raise ValueError(f"double/invalid release of host slot {s}")
            batch.add(s)
        self._free.extend(slots)
        self._free_set.update(slots)


class PageAllocator:
    """Host-side refcounted free-list allocator over pool pages (page 0
    reserved).

    A membership *set* shadows the LIFO stack so the double-free guard is
    O(1) per page instead of an O(n) list scan — freeing a long sequence's
    pages used to be quadratic in pool size. Every allocated page carries
    a reference count (`alloc` → 1, `incref` adds holders); `free`
    decrements and a page returns to the free list only at count zero, so
    prefix-shared pages survive until their last holder lets go.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("pool needs at least 2 pages (page 0 is scratch)")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, SCRATCH_PAGE, -1))
        self._free_set = set(self._free)
        self._refs: dict[int, int] = {}   # page → holders (allocated only)
        # telemetry: high-water mark of pages simultaneously in use (the
        # utilization headroom number the metrics snapshot reports)
        self.peak_in_use = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.capacity - len(self._free)

    @property
    def capacity(self) -> int:
        """Allocatable pages (excludes the scratch page)."""
        return self.n_pages - 1

    def reset_peak(self):
        """Restart the high-water mark at the current level (measurement
        window boundary, used by `ServeEngine.reset_metrics`)."""
        self.peak_in_use = self.in_use

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise MemoryError(f"page pool exhausted: need {n}, "
                              f"free {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(out)
        for p in out:
            self._refs[p] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return out

    def incref(self, pages: list[int]):
        """Add one holder to each (allocated) page — validated as a
        batch before mutating, like `free`."""
        for p in pages:
            if p <= SCRATCH_PAGE or p >= self.n_pages \
                    or p in self._free_set:
                raise ValueError(f"incref of unallocated page {p}")
        for p in pages:
            self._refs[p] += 1

    def refcount(self, page: int) -> int:
        """Current holder count (0 for free pages)."""
        return self._refs.get(page, 0) if page not in self._free_set else 0

    @property
    def n_shared(self) -> int:
        """Pages currently held by more than one owner (telemetry)."""
        return sum(1 for c in self._refs.values() if c > 1)

    def free(self, pages: list[int]) -> list[int]:
        """Drop one reference per page; returns the pages whose count hit
        zero and were actually returned to the free list (exactly the set
        the caller must scrub — still-shared pages stay live and
        untouched)."""
        # validate the whole batch (including intra-batch duplicates)
        # before mutating, so a raise leaves the allocator consistent
        batch = set()
        for p in pages:
            if p <= SCRATCH_PAGE or p >= self.n_pages \
                    or p in self._free_set or p in batch:
                raise ValueError(f"double/invalid free of page {p}")
            batch.add(p)
        freed = []
        for p in pages:
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                freed.append(p)
        self._free.extend(freed)
        self._free_set.update(freed)
        return freed


class RegisterAllocator:
    """Free-list allocator over register slots — the `PageAllocator`
    sibling for the fixed-size state kind (slot 0 reserved as scratch).

    A sequence holds exactly one slot for its whole lifetime, so slots are
    allocated/freed one at a time and capacity equals the engine's
    max-concurrent-sequences bound.
    """

    def __init__(self, n_slots: int):
        if n_slots < 2:
            raise ValueError("register pool needs at least 2 slots "
                             "(slot 0 is scratch)")
        self.n_slots = n_slots
        self._free = list(range(n_slots - 1, SCRATCH_SLOT, -1))
        self.peak_in_use = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.capacity - len(self._free)

    @property
    def capacity(self) -> int:
        """Allocatable slots (excludes the scratch slot)."""
        return self.n_slots - 1

    def reset_peak(self):
        self.peak_in_use = self.in_use

    def alloc(self) -> int:
        if not self._free:
            raise MemoryError("register slots exhausted")
        out = self._free.pop()
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return out

    def free(self, slot: int):
        if slot <= SCRATCH_SLOT or slot >= self.n_slots \
                or slot in self._free:
            raise ValueError(f"double/invalid free of register slot {slot}")
        self._free.append(slot)


@jax.jit
def gather_pages(pool: Params, block_tables: jnp.ndarray) -> Params:
    """Gather pages into contiguous per-sequence slabs (TEST ORACLE ONLY —
    the serving path is block-table-native and never materialises slabs).

    pool leaves: [n_layers, n_pages, page_size, ...]
    block_tables: [B, P] int32 page ids (pad entries = SCRATCH_PAGE)
    returns leaves: [n_layers, B, P·page_size, ...]
    """
    b, p = block_tables.shape

    def g(leaf):
        s = jnp.take(leaf, block_tables.reshape(-1), axis=1)
        return s.reshape(leaf.shape[0], b, p * leaf.shape[2], *leaf.shape[3:])

    return jax.tree.map(g, pool)


@jax.jit
def scatter_decode_rows(pool: Params, slab: Params, fill_pos: jnp.ndarray,
                        page_ids: jnp.ndarray, offsets: jnp.ndarray) -> Params:
    """Write each slot's newly decoded KV row back into its page (TEST
    ORACLE ONLY — the forward writes rows in place on the serving path).

    Extracts row `fill_pos[i]` of slot i from every slab leaf and stores it
    at (page_ids[i], offsets[i]) in the pool. Padded slots point at the
    scratch page, so their (duplicate) writes are harmless.
    """
    rows = jnp.arange(fill_pos.shape[0])

    def upd(p, s):
        new = s[:, rows, fill_pos]                 # [n_layers, B, ...]
        return p.at[:, page_ids, offsets].set(new.astype(p.dtype))

    return jax.tree.map(upd, pool, slab)


@jax.jit
def scatter_prefill_rows(pool: Params, slab: Params, positions: jnp.ndarray,
                         page_ids: jnp.ndarray,
                         offsets: jnp.ndarray) -> Params:
    """Write a prefill chunk's KV rows (single sequence, slab batch row 0)
    back into its pages: slab positions `positions[j]` land at
    (page_ids[j], offsets[j]). TEST ORACLE ONLY — see gather_pages."""

    def upd(p, s):
        new = s[:, 0, positions]                   # [n_layers, S, ...]
        return p.at[:, page_ids, offsets].set(new.astype(p.dtype))

    return jax.tree.map(upd, pool, slab)


class PagedKVCache:
    """Partitioned state + allocators + per-sequence block tables and
    register-slot map for one served model.

    `state` is the `{"kv": ..., "register": ...}` pytree the adapter's
    `init_state` built (a bare kv pool is accepted and wrapped, for the
    test oracles that only exercise the kv bookkeeping). `pool` aliases
    `state["kv"]` for the kv-only callers.
    """

    def __init__(self, state: Params, n_pages: int, page_size: int,
                 n_slots: int = 0):
        if not (isinstance(state, dict) and set(state) == {"kv", "register"}):
            state = {"kv": state, "register": {}}
        self.state = state
        self.page_size = page_size
        self.allocator = PageAllocator(n_pages)
        # table entries are device page ids (int) or HostPageRef — the
        # per-page residency ledger lives in the tables themselves
        self.tables: dict[int, list[int | HostPageRef]] = {}
        self.has_register = bool(jax.tree.leaves(state["register"]))
        self.registers = RegisterAllocator(n_slots) if self.has_register \
            else None
        self.slots: dict[int, int] = {}
        # host swap tier: absent until the engine attaches a budget
        self.host_pool: HostSwapPool | None = None
        # device page ids inside a swap-transfer window right now; scrub
        # and cow assert against touching them
        self._inflight: set[int] = set()
        # bytes one page costs across every kv leaf (the swap cost unit)
        self.page_bytes = sum(
            a.shape[0] * int(np.prod(a.shape[2:], dtype=np.int64))
            * np.dtype(a.dtype).itemsize
            for a in jax.tree.leaves(state["kv"]))
        # telemetry: release-time scrub totals (pages / register slots
        # zeroed) and swap traffic, mirrored into the metrics snapshot
        self.pages_scrubbed = 0
        self.slots_scrubbed = 0
        self.pages_swapped_out = 0
        self.pages_swapped_in = 0
        # fused state-maintenance dispatches, compiled once per padded
        # page-count (scrub) and once at all (cow); both donate the state
        # so a pool sized to fill HBM never needs a second live copy
        self._scrub_jit = jax.jit(_scrub_impl, donate_argnums=(0,),
                                  static_argnames=("do_slot",))
        self._cow_jit = jax.jit(_cow_impl, donate_argnums=(0,))
        # swap transfers: the gather reads (state survives for the deref
        # that follows), the scatter donates like scrub
        self._swap_gather_jit = jax.jit(_swap_gather_impl)
        self._swap_scatter_jit = jax.jit(_swap_scatter_impl,
                                         donate_argnums=(0,))

    @property
    def pool(self) -> Params:
        return self.state["kv"]

    @pool.setter
    def pool(self, value: Params):
        self.state["kv"] = value

    def open(self, rid: int):
        if rid in self.tables:
            raise ValueError(f"sequence {rid} already open")
        self.tables[rid] = []
        if self.registers is not None:
            self.slots[rid] = self.registers.alloc()

    def ensure(self, rid: int, n_tokens: int):
        """Grow `rid`'s block table to cover `n_tokens` positions."""
        table = self.tables[rid]
        need = pages_for(n_tokens, self.page_size) - len(table)
        if need > 0:
            table.extend(self.allocator.alloc(need))

    def release(self, rid: int, adopted: int = 0):
        """Return `rid`'s pages and register slot. The first `adopted`
        table entries' references were taken over by another holder (the
        radix prefix tree) and are skipped; the rest are deref'd, and
        only pages that dropped to refcount 0 are scrubbed — together
        with the register slot — in one fused dispatch. Host-resident
        entries (a swapped-out sequence being cancelled, expired, or
        degraded to replay) have no device reference: their host slots
        are simply returned to the swap tier."""
        entries = self.tables.pop(rid)[adopted:]
        slot = self.slots.pop(rid, None)
        self.deref([p for p in entries if isinstance(p, int)], slot)
        host_slots = [e.slot for e in entries
                      if isinstance(e, HostPageRef)]
        if host_slots:
            self.host_pool.release(host_slots)
        if slot is not None:
            self.registers.free(slot)

    def deref(self, pages: list[int], slot: int | None = None):
        """Drop one reference per page; scrub whatever actually freed
        (refcount hit 0) plus `slot`, in one fused dispatch."""
        freed = self.allocator.free(pages)
        self.scrub(freed, slot)

    def scrub(self, pages: list[int], slot: int | None):
        """Zero released state rows of BOTH kinds — in ONE fused jit
        dispatch per call — so a recycled page or slot can never leak its
        predecessor's state.

        For register leaves this is load-bearing: the next sequence reads
        its slot's full state at admission (the SSM carried conv/SSD
        state), so stale rows would silently contaminate it. Freed KV
        pages are only ever re-read after being overwritten (the causal
        mask / seq_lengths hide rows past the fill point), so their zeroing
        is defence in depth through the same method.

        Callers must pass only *exclusively-owned* state: the engine
        hands in exactly the pages `PageAllocator.free` reported as
        dropping to refcount 0 — scrubbing a still-shared page would
        corrupt every surviving holder. Page indices are padded to the
        next power of two with the scratch page (whose content is
        garbage by contract, so the dead extra zeroing is harmless and
        the jit variant count stays bounded); the whole call is tallied
        as one `scrub_state` dispatch in the `kernels.ops` counts.
        """
        bad = set(pages) & self._inflight
        assert not bad, f"scrub of in-flight swap page(s) {sorted(bad)}"
        for p in pages:
            assert self.allocator.refcount(p) == 0, \
                f"scrub of still-referenced page {p}"
        has_kv = bool(pages) and bool(jax.tree.leaves(self.state["kv"]))
        do_slot = slot is not None \
            and bool(jax.tree.leaves(self.state["register"]))
        if not has_kv and not do_slot:
            return
        padded = _next_pow2(len(pages)) if has_kv else 1
        idx = jnp.asarray(
            (pages + [SCRATCH_PAGE] * (padded - len(pages))) if has_kv
            else [SCRATCH_PAGE], jnp.int32)
        kops._record_dispatch("scrub_state")
        self.state = self._scrub_jit(
            self.state, idx,
            jnp.asarray(slot if do_slot else SCRATCH_SLOT, jnp.int32),
            do_slot=do_slot)
        if has_kv:
            self.pages_scrubbed += len(pages)
        if slot is not None:
            self.slots_scrubbed += 1

    def cow_copy(self, src: int, dst: int):
        """Copy-on-write primitive: duplicate page `src` into `dst`
        across every kv leaf in one fused dispatch (tallied as
        `cow_page_copy`). The caller owns `dst` exclusively and may then
        overwrite rows past the shared prefix without perturbing `src`'s
        other holders. Both ends must be live device pages: a host
        resident page has no device id at all, so a `HostPageRef` can
        never reach here — the asserts pin the residency contract (COW
        never targets a swapped or in-flight source)."""
        assert src not in self._inflight and dst not in self._inflight, \
            f"cow touching in-flight swap page ({src} -> {dst})"
        assert self.allocator.refcount(src) >= 1, \
            f"cow from unallocated (or host-resident) page {src}"
        kops._record_dispatch("cow_page_copy")
        self.state = self._cow_jit(self.state, jnp.asarray(src, jnp.int32),
                                   jnp.asarray(dst, jnp.int32))

    # ------------------------------------------------------------------
    # host swap tier
    # ------------------------------------------------------------------

    def attach_host_pool(self, host_mb: float) -> HostSwapPool:
        """Create the host swap tier under a `host_mb` MiB budget (page
        capacity = budget // page_bytes; a budget smaller than one page
        yields capacity 0, gracefully disabling swap-out)."""
        self.host_pool = HostSwapPool(self.state["kv"],
                                      int(host_mb * 2 ** 20))
        return self.host_pool

    def residency(self, rid: int) -> list[str]:
        """Per-table-entry residency of `rid`: "device", "host", or
        "in_flight" (the ledger view the tests and probes read)."""
        out = []
        for e in self.tables[rid]:
            if isinstance(e, HostPageRef):
                out.append("host")
            elif e in self._inflight:
                out.append("in_flight")
            else:
                out.append("device")
        return out

    def swap_eligible_pages(self, rid: int) -> list[int]:
        """Device pages of `rid` that swap-out would move: exactly the
        exclusively-held ones (refcount 1). Shared pages — radix-tree or
        sibling references — keep the victim's retained ref and stay
        device resident, so a shared page swaps at most once and a COW
        source is never host resident."""
        alloc = self.allocator
        return [p for p in self.tables[rid]
                if isinstance(p, int) and alloc.refcount(p) == 1]

    def swap_out(self, rid: int) -> tuple[int, int]:
        """Move `rid`'s exclusively-held pages to the host tier; returns
        `(pages_moved, bytes_moved)`.

        One fused gather dispatch (tallied `swap_out`) + one
        `device_get` moves the whole set; the table entries become
        `HostPageRef`s in place and the device copies are deref'd —
        dropping the sole reference, so they scrub and return to the
        allocator. Host slots are claimed *before* the transfer
        (`MemoryError` on an over-budget tier mutates nothing)."""
        if self.host_pool is None:
            raise RuntimeError("no host swap pool attached")
        table = self.tables[rid]
        moved = [(i, p) for i, p in enumerate(table)
                 if isinstance(p, int) and self.allocator.refcount(p) == 1]
        if not moved:
            return 0, 0
        pages = [p for _, p in moved]
        slots = self.host_pool.take(len(pages))
        padded = _next_pow2(len(pages))
        idx = jnp.asarray(pages + [SCRATCH_PAGE] * (padded - len(pages)),
                          jnp.int32)
        self._inflight.update(pages)
        try:
            kops._record_dispatch("swap_out")
            blocks = jax.device_get(self._swap_gather_jit(self.state, idx))
            self.host_pool.store(
                slots, jax.tree.map(lambda a: a[:, :len(pages)], blocks))
        finally:
            self._inflight.difference_update(pages)
        for (i, _), s in zip(moved, slots):
            table[i] = HostPageRef(s)
        self.deref(pages)
        self.pages_swapped_out += len(pages)
        return len(pages), len(pages) * self.page_bytes

    def swap_in(self, rid: int,
                alloc_fn: Callable[[int], list[int]] | None = None
                ) -> tuple[int, int]:
        """Restore `rid`'s host-resident pages to the device tier;
        returns `(pages_moved, bytes_moved)`.

        Fresh device pages are allocated first — through `alloc_fn` when
        the caller has a smarter allocator (the scheduler's tree-evicting
        one) — so a `MemoryError` leaves table, host tier, and allocator
        untouched. One `device_put` + fused scatter dispatch (tallied
        `swap_in`) writes the blocks back, the block-table row is patched
        in place (bit-identical continuation: the pages hold the same
        rows they held before swap-out), and the host slots are freed."""
        table = self.tables[rid]
        refs = [(i, e) for i, e in enumerate(table)
                if isinstance(e, HostPageRef)]
        if not refs:
            return 0, 0
        slots = [e.slot for _, e in refs]
        new_pages = (alloc_fn or self.allocator.alloc)(len(refs))
        pad = _next_pow2(len(new_pages)) - len(new_pages)
        blocks = self.host_pool.load(slots)
        if pad:
            blocks = jax.tree.map(
                lambda b: np.concatenate(
                    [b, np.zeros((b.shape[0], pad) + b.shape[2:], b.dtype)],
                    axis=1), blocks)
        idx = jnp.asarray([p for p in new_pages]
                          + [SCRATCH_PAGE] * pad, jnp.int32)
        self._inflight.update(new_pages)
        try:
            kops._record_dispatch("swap_in")
            self.state = self._swap_scatter_jit(
                self.state, jax.device_put(blocks), idx)
        finally:
            self._inflight.difference_update(new_pages)
        for (i, _), p in zip(refs, new_pages):
            table[i] = p
        self.host_pool.release(slots)
        self.pages_swapped_in += len(refs)
        return len(refs), len(refs) * self.page_bytes

    def page_of(self, rid: int, position: int) -> tuple[int, int]:
        """(page id, in-page offset) holding `position` of sequence `rid`."""
        return (self.tables[rid][position // self.page_size],
                position % self.page_size)

    def block_table_array(self, rids: list[int | None],
                          n_cols: int) -> jnp.ndarray:
        """[len(rids), n_cols] int32 table, short rows padded with scratch.
        `None` entries are padded batch rows (all-scratch).

        A row longer than `n_cols` is an error, never a silent truncation:
        a too-narrow table would drop live pages from the kernel's walk
        (and from the write targeting) without any visible failure.
        """
        bt = [self.tables[r] if r is not None else [] for r in rids]
        for r, row in zip(rids, bt):
            if len(row) > n_cols:
                raise ValueError(
                    f"block table for sequence {r} holds {len(row)} pages "
                    f"but only {n_cols} columns were requested")
            if any(not isinstance(p, int) for p in row):
                raise ValueError(
                    f"sequence {r} has host-resident pages; it must swap "
                    f"in before any kernel dispatch")
        bt = [row + [SCRATCH_PAGE] * (n_cols - len(row)) for row in bt]
        return jnp.asarray(bt, jnp.int32)

    def register_index_array(self, rids: list[int | None]) -> jnp.ndarray:
        """[len(rids)] int32 register slot per batch row; `None` (padded)
        rows point at the scratch slot, so their dead writes never touch a
        live sequence's state."""
        return jnp.asarray(
            [self.slots[r] if r is not None else SCRATCH_SLOT for r in rids],
            jnp.int32)
