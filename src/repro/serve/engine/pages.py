"""Paged serving state: KV pages + fixed-size register slots, per sequence.

The engine's device-side state is one partitioned pytree per served model,
`{"kv": ..., "register": ...}`, because architectures carry two different
kinds of per-sequence state:

  * **kv** leaves grow with sequence length. They live in a single *page
    pool* per leaf — shape `[n_layers, n_pages, page_size, ...]` — with a
    host-side block table per sequence mapping logical positions to pages.
    Pages are allocated lazily as a sequence grows and freed on completion,
    so pool HBM is shared across sequences of very different lengths (the
    vLLM PagedAttention memory model). Dense/MoE attention caches are pure
    kv; a hybrid's shared-attention cache is its kv part.
  * **register** leaves are fixed-size per sequence — a Mamba2 layer's conv
    tail `[W-1, conv_dim]` and SSD state `[H, N, P]` do not grow with
    context. They live in *slot pools* — `[n_layers, n_slots, ...]` — and a
    sequence is assigned one register slot at admission, carried until
    release. No block table: the slot id indexes axis 1 of every register
    leaf directly. Pure-SSM models are all register; hybrids mix both kinds
    in one state pytree.

The kv data path is block-table-native: the scheduler hands the pool and
the per-sequence block-table rows straight to the backend's
`forward_chunk`, which scatters each new KV row into its page and attends
by walking the table inside `kernels.ops.paged_attention` (one Mosaic
kernel on TPU: the page ids are scalar-prefetched and each page is DMA'd
into VMEM exactly once, with online softmax across the walk). No
contiguous slab is ever materialised. Register leaves are gathered by slot
index at the top of the forward and scattered back once per call.

Both pools are format-agnostic: they are built by the adapter's
`init_state(n_pages, page_size, n_slots)` — the page/slot axis *is* the
batch axis — so the same machinery pages the bf16 cache ({k, v}), the
asymmetric per-(position, head) int8/int4 KV cache (codes *and* their
scale/zero rows), and the SSM conv/SSD slot pools.

This module keeps the *bookkeeping*: the two allocators, block tables and
register-slot maps, and release-time scrubbing. KV pages are
**refcounted** so many sequences — and the radix prefix cache
(`radix.RadixCache`) — can point at the same immutable prefix page:
`alloc()` hands out pages at refcount 1, `incref()` adds a holder, and
`free()` *decrements*, returning a page to the free list only when its
count hits zero (the list of pages that actually dropped to zero is
`free()`'s return value). The refcount/copy-on-write contract is:

  * a page is only ever *written* by a holder that owns it exclusively
    (refcount 1): freshly-allocated pages, or a private copy made by the
    scheduler's copy-on-write dispatch before extending a shared page;
  * shared pages (refcount > 1) are immutable until every holder has
    dropped its reference — so releasing one sharer can never perturb
    the bits another sharer (or the prefix tree) is still reading;
  * **scrub-on-release applies only to exclusively-owned state**: the
    fused `scrub()` dispatch zeroes exactly the pages `free()` reported
    as dropping to refcount 0, plus the released register slot. Zeroing
    a still-referenced page would corrupt live readers; skipping the
    zero on an exclusively-freed one would leak state into its next
    owner (load-bearing for register slots, defence in depth for KV).

Register slots are *excluded* from all sharing: SSM conv/SSD state is a
position-dependent running summary, not an addressable prefix, so a slot
always has exactly one owner and is scrubbed on every release.

The same `release()`/`scrub()` path serves normal completion,
cancellation, and preemption — a preempted victim's shared pages are
simply unpinned (deref'd, never scrubbed) while its exclusive pages are
zeroed and returned; its state is recomputed later by replaying the
host-known token stream, so the allocator never needs a swap-out notion.
`release(rid, adopted=k)` lets the prefix tree take over the request's
reference on its first `k` pages instead of dropping them. `alloc()`
validates before mutating: `MemoryError` on exhaustion leaves the free
list untouched, which is what lets the scheduler evict cached prefixes
or preempt a victim and simply retry. Each release scrubs through ONE
fused jit dispatch (pages of every kv leaf + the register slot together,
page counts padded to powers of two to bound the jit variants), tallied
as `scrub_state` in the `kernels.ops` dispatch counts. The legacy
`gather_pages` / `scatter_*_rows` primitives survive purely as the test
oracle the paged kernel is checked against.

Page 0 / slot 0 are reserved as scratch: padded batch rows (inactive
slots) and padded block-table entries point at them, so their masked
reads and dead writes can never touch a live sequence's state (which is
also what makes scratch-padded scrub index vectors harmless).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

Params = dict[str, Any]

SCRATCH_PAGE = 0
SCRATCH_SLOT = 0


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold `n_tokens` KV rows."""
    return -(-n_tokens // page_size)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _scrub_impl(state: Params, page_idx: jnp.ndarray, slot: jnp.ndarray,
                *, do_slot: bool) -> Params:
    """One fused dispatch zeroing `page_idx` rows of every kv leaf and —
    when `do_slot` — slot `slot` of every register leaf. `page_idx` may
    be scratch-padded (zeroing the scratch page is a harmless dead
    write); `slot` is scratch when only pages are scrubbed."""
    kv = jax.tree.map(
        lambda a: a.at[:, page_idx].set(jnp.zeros((), a.dtype)),
        state["kv"])
    register = state["register"]
    if do_slot:
        register = jax.tree.map(
            lambda a: a.at[:, slot].set(jnp.zeros((), a.dtype)), register)
    return {"kv": kv, "register": register}


def _cow_impl(state: Params, src: jnp.ndarray, dst: jnp.ndarray) -> Params:
    """Copy page `src` into page `dst` on every kv leaf (one dispatch)."""
    return {"kv": jax.tree.map(
        lambda a: a.at[:, dst].set(
            jax.lax.dynamic_index_in_dim(a, src, axis=1, keepdims=False)),
        state["kv"]), "register": state["register"]}


class PageAllocator:
    """Host-side refcounted free-list allocator over pool pages (page 0
    reserved).

    A membership *set* shadows the LIFO stack so the double-free guard is
    O(1) per page instead of an O(n) list scan — freeing a long sequence's
    pages used to be quadratic in pool size. Every allocated page carries
    a reference count (`alloc` → 1, `incref` adds holders); `free`
    decrements and a page returns to the free list only at count zero, so
    prefix-shared pages survive until their last holder lets go.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("pool needs at least 2 pages (page 0 is scratch)")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, SCRATCH_PAGE, -1))
        self._free_set = set(self._free)
        self._refs: dict[int, int] = {}   # page → holders (allocated only)
        # telemetry: high-water mark of pages simultaneously in use (the
        # utilization headroom number the metrics snapshot reports)
        self.peak_in_use = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.capacity - len(self._free)

    @property
    def capacity(self) -> int:
        """Allocatable pages (excludes the scratch page)."""
        return self.n_pages - 1

    def reset_peak(self):
        """Restart the high-water mark at the current level (measurement
        window boundary, used by `ServeEngine.reset_metrics`)."""
        self.peak_in_use = self.in_use

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise MemoryError(f"page pool exhausted: need {n}, "
                              f"free {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(out)
        for p in out:
            self._refs[p] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return out

    def incref(self, pages: list[int]):
        """Add one holder to each (allocated) page — validated as a
        batch before mutating, like `free`."""
        for p in pages:
            if p <= SCRATCH_PAGE or p >= self.n_pages \
                    or p in self._free_set:
                raise ValueError(f"incref of unallocated page {p}")
        for p in pages:
            self._refs[p] += 1

    def refcount(self, page: int) -> int:
        """Current holder count (0 for free pages)."""
        return self._refs.get(page, 0) if page not in self._free_set else 0

    @property
    def n_shared(self) -> int:
        """Pages currently held by more than one owner (telemetry)."""
        return sum(1 for c in self._refs.values() if c > 1)

    def free(self, pages: list[int]) -> list[int]:
        """Drop one reference per page; returns the pages whose count hit
        zero and were actually returned to the free list (exactly the set
        the caller must scrub — still-shared pages stay live and
        untouched)."""
        # validate the whole batch (including intra-batch duplicates)
        # before mutating, so a raise leaves the allocator consistent
        batch = set()
        for p in pages:
            if p <= SCRATCH_PAGE or p >= self.n_pages \
                    or p in self._free_set or p in batch:
                raise ValueError(f"double/invalid free of page {p}")
            batch.add(p)
        freed = []
        for p in pages:
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                freed.append(p)
        self._free.extend(freed)
        self._free_set.update(freed)
        return freed


class RegisterAllocator:
    """Free-list allocator over register slots — the `PageAllocator`
    sibling for the fixed-size state kind (slot 0 reserved as scratch).

    A sequence holds exactly one slot for its whole lifetime, so slots are
    allocated/freed one at a time and capacity equals the engine's
    max-concurrent-sequences bound.
    """

    def __init__(self, n_slots: int):
        if n_slots < 2:
            raise ValueError("register pool needs at least 2 slots "
                             "(slot 0 is scratch)")
        self.n_slots = n_slots
        self._free = list(range(n_slots - 1, SCRATCH_SLOT, -1))
        self.peak_in_use = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.capacity - len(self._free)

    @property
    def capacity(self) -> int:
        """Allocatable slots (excludes the scratch slot)."""
        return self.n_slots - 1

    def reset_peak(self):
        self.peak_in_use = self.in_use

    def alloc(self) -> int:
        if not self._free:
            raise MemoryError("register slots exhausted")
        out = self._free.pop()
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return out

    def free(self, slot: int):
        if slot <= SCRATCH_SLOT or slot >= self.n_slots \
                or slot in self._free:
            raise ValueError(f"double/invalid free of register slot {slot}")
        self._free.append(slot)


@jax.jit
def gather_pages(pool: Params, block_tables: jnp.ndarray) -> Params:
    """Gather pages into contiguous per-sequence slabs (TEST ORACLE ONLY —
    the serving path is block-table-native and never materialises slabs).

    pool leaves: [n_layers, n_pages, page_size, ...]
    block_tables: [B, P] int32 page ids (pad entries = SCRATCH_PAGE)
    returns leaves: [n_layers, B, P·page_size, ...]
    """
    b, p = block_tables.shape

    def g(leaf):
        s = jnp.take(leaf, block_tables.reshape(-1), axis=1)
        return s.reshape(leaf.shape[0], b, p * leaf.shape[2], *leaf.shape[3:])

    return jax.tree.map(g, pool)


@jax.jit
def scatter_decode_rows(pool: Params, slab: Params, fill_pos: jnp.ndarray,
                        page_ids: jnp.ndarray, offsets: jnp.ndarray) -> Params:
    """Write each slot's newly decoded KV row back into its page (TEST
    ORACLE ONLY — the forward writes rows in place on the serving path).

    Extracts row `fill_pos[i]` of slot i from every slab leaf and stores it
    at (page_ids[i], offsets[i]) in the pool. Padded slots point at the
    scratch page, so their (duplicate) writes are harmless.
    """
    rows = jnp.arange(fill_pos.shape[0])

    def upd(p, s):
        new = s[:, rows, fill_pos]                 # [n_layers, B, ...]
        return p.at[:, page_ids, offsets].set(new.astype(p.dtype))

    return jax.tree.map(upd, pool, slab)


@jax.jit
def scatter_prefill_rows(pool: Params, slab: Params, positions: jnp.ndarray,
                         page_ids: jnp.ndarray,
                         offsets: jnp.ndarray) -> Params:
    """Write a prefill chunk's KV rows (single sequence, slab batch row 0)
    back into its pages: slab positions `positions[j]` land at
    (page_ids[j], offsets[j]). TEST ORACLE ONLY — see gather_pages."""

    def upd(p, s):
        new = s[:, 0, positions]                   # [n_layers, S, ...]
        return p.at[:, page_ids, offsets].set(new.astype(p.dtype))

    return jax.tree.map(upd, pool, slab)


class PagedKVCache:
    """Partitioned state + allocators + per-sequence block tables and
    register-slot map for one served model.

    `state` is the `{"kv": ..., "register": ...}` pytree the adapter's
    `init_state` built (a bare kv pool is accepted and wrapped, for the
    test oracles that only exercise the kv bookkeeping). `pool` aliases
    `state["kv"]` for the kv-only callers.
    """

    def __init__(self, state: Params, n_pages: int, page_size: int,
                 n_slots: int = 0):
        if not (isinstance(state, dict) and set(state) == {"kv", "register"}):
            state = {"kv": state, "register": {}}
        self.state = state
        self.page_size = page_size
        self.allocator = PageAllocator(n_pages)
        self.tables: dict[int, list[int]] = {}
        self.has_register = bool(jax.tree.leaves(state["register"]))
        self.registers = RegisterAllocator(n_slots) if self.has_register \
            else None
        self.slots: dict[int, int] = {}
        # telemetry: release-time scrub totals (pages / register slots
        # zeroed), mirrored into the metrics snapshot as gauges
        self.pages_scrubbed = 0
        self.slots_scrubbed = 0
        # fused state-maintenance dispatches, compiled once per padded
        # page-count (scrub) and once at all (cow); both donate the state
        # so a pool sized to fill HBM never needs a second live copy
        self._scrub_jit = jax.jit(_scrub_impl, donate_argnums=(0,),
                                  static_argnames=("do_slot",))
        self._cow_jit = jax.jit(_cow_impl, donate_argnums=(0,))

    @property
    def pool(self) -> Params:
        return self.state["kv"]

    @pool.setter
    def pool(self, value: Params):
        self.state["kv"] = value

    def open(self, rid: int):
        if rid in self.tables:
            raise ValueError(f"sequence {rid} already open")
        self.tables[rid] = []
        if self.registers is not None:
            self.slots[rid] = self.registers.alloc()

    def ensure(self, rid: int, n_tokens: int):
        """Grow `rid`'s block table to cover `n_tokens` positions."""
        table = self.tables[rid]
        need = pages_for(n_tokens, self.page_size) - len(table)
        if need > 0:
            table.extend(self.allocator.alloc(need))

    def release(self, rid: int, adopted: int = 0):
        """Return `rid`'s pages and register slot. The first `adopted`
        table entries' references were taken over by another holder (the
        radix prefix tree) and are skipped; the rest are deref'd, and
        only pages that dropped to refcount 0 are scrubbed — together
        with the register slot — in one fused dispatch."""
        pages = self.tables.pop(rid)
        slot = self.slots.pop(rid, None)
        self.deref(pages[adopted:], slot)
        if slot is not None:
            self.registers.free(slot)

    def deref(self, pages: list[int], slot: int | None = None):
        """Drop one reference per page; scrub whatever actually freed
        (refcount hit 0) plus `slot`, in one fused dispatch."""
        freed = self.allocator.free(pages)
        self.scrub(freed, slot)

    def scrub(self, pages: list[int], slot: int | None):
        """Zero released state rows of BOTH kinds — in ONE fused jit
        dispatch per call — so a recycled page or slot can never leak its
        predecessor's state.

        For register leaves this is load-bearing: the next sequence reads
        its slot's full state at admission (the SSM carried conv/SSD
        state), so stale rows would silently contaminate it. Freed KV
        pages are only ever re-read after being overwritten (the causal
        mask / seq_lengths hide rows past the fill point), so their zeroing
        is defence in depth through the same method.

        Callers must pass only *exclusively-owned* state: the engine
        hands in exactly the pages `PageAllocator.free` reported as
        dropping to refcount 0 — scrubbing a still-shared page would
        corrupt every surviving holder. Page indices are padded to the
        next power of two with the scratch page (whose content is
        garbage by contract, so the dead extra zeroing is harmless and
        the jit variant count stays bounded); the whole call is tallied
        as one `scrub_state` dispatch in the `kernels.ops` counts.
        """
        has_kv = bool(pages) and bool(jax.tree.leaves(self.state["kv"]))
        do_slot = slot is not None \
            and bool(jax.tree.leaves(self.state["register"]))
        if not has_kv and not do_slot:
            return
        padded = _next_pow2(len(pages)) if has_kv else 1
        idx = jnp.asarray(
            (pages + [SCRATCH_PAGE] * (padded - len(pages))) if has_kv
            else [SCRATCH_PAGE], jnp.int32)
        kops._record_dispatch("scrub_state")
        self.state = self._scrub_jit(
            self.state, idx,
            jnp.asarray(slot if do_slot else SCRATCH_SLOT, jnp.int32),
            do_slot=do_slot)
        if has_kv:
            self.pages_scrubbed += len(pages)
        if slot is not None:
            self.slots_scrubbed += 1

    def cow_copy(self, src: int, dst: int):
        """Copy-on-write primitive: duplicate page `src` into `dst`
        across every kv leaf in one fused dispatch (tallied as
        `cow_page_copy`). The caller owns `dst` exclusively and may then
        overwrite rows past the shared prefix without perturbing `src`'s
        other holders."""
        kops._record_dispatch("cow_page_copy")
        self.state = self._cow_jit(self.state, jnp.asarray(src, jnp.int32),
                                   jnp.asarray(dst, jnp.int32))

    def page_of(self, rid: int, position: int) -> tuple[int, int]:
        """(page id, in-page offset) holding `position` of sequence `rid`."""
        return (self.tables[rid][position // self.page_size],
                position % self.page_size)

    def block_table_array(self, rids: list[int | None],
                          n_cols: int) -> jnp.ndarray:
        """[len(rids), n_cols] int32 table, short rows padded with scratch.
        `None` entries are padded batch rows (all-scratch).

        A row longer than `n_cols` is an error, never a silent truncation:
        a too-narrow table would drop live pages from the kernel's walk
        (and from the write targeting) without any visible failure.
        """
        bt = [self.tables[r] if r is not None else [] for r in rids]
        for r, row in zip(rids, bt):
            if len(row) > n_cols:
                raise ValueError(
                    f"block table for sequence {r} holds {len(row)} pages "
                    f"but only {n_cols} columns were requested")
        bt = [row + [SCRATCH_PAGE] * (n_cols - len(row)) for row in bt]
        return jnp.asarray(bt, jnp.int32)

    def register_index_array(self, rids: list[int | None]) -> jnp.ndarray:
        """[len(rids)] int32 register slot per batch row; `None` (padded)
        rows point at the scratch slot, so their dead writes never touch a
        live sequence's state."""
        return jnp.asarray(
            [self.slots[r] if r is not None else SCRATCH_SLOT for r in rids],
            jnp.int32)
