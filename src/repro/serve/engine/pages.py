"""Paged KV cache: fixed-size pages, per-sequence block tables, allocator.

Instead of one dense `[slots, max_len]` KV region per slot, the engine owns a
single device-side *page pool* per KV leaf — shape `[n_layers, n_pages,
page_size, ...]` — and a host-side block table per sequence mapping logical
positions to pages. Pages are allocated lazily as a sequence grows and freed
on completion, so pool HBM is shared across sequences of very different
lengths (the vLLM PagedAttention memory model).

The pool is format-agnostic: it is built by calling the adapter's
`init_cache(n_pages, page_size)` — the page axis *is* the batch axis — so
the same machinery pages the bf16 cache ({k, v}) and the asymmetric
per-(position, head) int8/int4 KV cache ({k, v, k_scale, v_scale, k_zero, v_zero}): integer
pages carry their codes *and* their scale/zero rows.

The data path is block-table-native: the scheduler hands the pool and the
per-sequence block-table rows straight to the backend's `forward_chunk`,
which scatters each new KV row into its page and attends by walking the
table inside `kernels.ops.paged_attention` (one Mosaic kernel on TPU: the
page ids are scalar-prefetched and each page is DMA'd into VMEM exactly
once, with online softmax across the walk). No contiguous
`[n_layers, B, P·page_size, ...]` slab is ever materialised. This module
therefore only keeps the *bookkeeping* — allocator + block tables — plus
the legacy `gather_pages` / `scatter_*_rows` primitives, which survive
purely as the test oracle the paged kernel is checked against.

Page 0 is reserved as a scratch page: padded batch rows (inactive slots) and
padded block-table entries point at it, so their masked reads and dead
writes can never touch a live sequence's KV.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]

SCRATCH_PAGE = 0


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold `n_tokens` KV rows."""
    return -(-n_tokens // page_size)


class PageAllocator:
    """Host-side free-list allocator over pool pages (page 0 reserved).

    A membership *set* shadows the LIFO stack so the double-free guard is
    O(1) per page instead of an O(n) list scan — freeing a long sequence's
    pages used to be quadratic in pool size.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("pool needs at least 2 pages (page 0 is scratch)")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, SCRATCH_PAGE, -1))
        self._free_set = set(self._free)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def capacity(self) -> int:
        """Allocatable pages (excludes the scratch page)."""
        return self.n_pages - 1

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise MemoryError(f"page pool exhausted: need {n}, "
                              f"free {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(out)
        return out

    def free(self, pages: list[int]):
        # validate the whole batch (including intra-batch duplicates)
        # before mutating, so a raise leaves the allocator consistent
        batch = set()
        for p in pages:
            if p <= SCRATCH_PAGE or p >= self.n_pages \
                    or p in self._free_set or p in batch:
                raise ValueError(f"double/invalid free of page {p}")
            batch.add(p)
        self._free.extend(pages)
        self._free_set.update(batch)


@jax.jit
def gather_pages(pool: Params, block_tables: jnp.ndarray) -> Params:
    """Gather pages into contiguous per-sequence slabs (TEST ORACLE ONLY —
    the serving path is block-table-native and never materialises slabs).

    pool leaves: [n_layers, n_pages, page_size, ...]
    block_tables: [B, P] int32 page ids (pad entries = SCRATCH_PAGE)
    returns leaves: [n_layers, B, P·page_size, ...]
    """
    b, p = block_tables.shape

    def g(leaf):
        s = jnp.take(leaf, block_tables.reshape(-1), axis=1)
        return s.reshape(leaf.shape[0], b, p * leaf.shape[2], *leaf.shape[3:])

    return jax.tree.map(g, pool)


@jax.jit
def scatter_decode_rows(pool: Params, slab: Params, fill_pos: jnp.ndarray,
                        page_ids: jnp.ndarray, offsets: jnp.ndarray) -> Params:
    """Write each slot's newly decoded KV row back into its page (TEST
    ORACLE ONLY — the forward writes rows in place on the serving path).

    Extracts row `fill_pos[i]` of slot i from every slab leaf and stores it
    at (page_ids[i], offsets[i]) in the pool. Padded slots point at the
    scratch page, so their (duplicate) writes are harmless.
    """
    rows = jnp.arange(fill_pos.shape[0])

    def upd(p, s):
        new = s[:, rows, fill_pos]                 # [n_layers, B, ...]
        return p.at[:, page_ids, offsets].set(new.astype(p.dtype))

    return jax.tree.map(upd, pool, slab)


@jax.jit
def scatter_prefill_rows(pool: Params, slab: Params, positions: jnp.ndarray,
                         page_ids: jnp.ndarray,
                         offsets: jnp.ndarray) -> Params:
    """Write a prefill chunk's KV rows (single sequence, slab batch row 0)
    back into its pages: slab positions `positions[j]` land at
    (page_ids[j], offsets[j]). TEST ORACLE ONLY — see gather_pages."""

    def upd(p, s):
        new = s[:, 0, positions]                   # [n_layers, S, ...]
        return p.at[:, page_ids, offsets].set(new.astype(p.dtype))

    return jax.tree.map(upd, pool, slab)


class PagedKVCache:
    """Pool + allocator + per-sequence block tables for one served model."""

    def __init__(self, pool: Params, n_pages: int, page_size: int):
        self.pool = pool
        self.page_size = page_size
        self.allocator = PageAllocator(n_pages)
        self.tables: dict[int, list[int]] = {}

    def open(self, rid: int):
        if rid in self.tables:
            raise ValueError(f"sequence {rid} already open")
        self.tables[rid] = []

    def ensure(self, rid: int, n_tokens: int):
        """Grow `rid`'s block table to cover `n_tokens` positions."""
        table = self.tables[rid]
        need = pages_for(n_tokens, self.page_size) - len(table)
        if need > 0:
            table.extend(self.allocator.alloc(need))

    def release(self, rid: int):
        self.allocator.free(self.tables.pop(rid))

    def page_of(self, rid: int, position: int) -> tuple[int, int]:
        """(page id, in-page offset) holding `position` of sequence `rid`."""
        return (self.tables[rid][position // self.page_size],
                position % self.page_size)

    def block_table_array(self, rids: list[int], n_cols: int) -> jnp.ndarray:
        """[len(rids), n_cols] int32 table, short rows padded with scratch.

        A row longer than `n_cols` is an error, never a silent truncation:
        a too-narrow table would drop live pages from the kernel's walk
        (and from the write targeting) without any visible failure.
        """
        bt = [self.tables[r] if r is not None else [] for r in rids]
        for r, row in zip(rids, bt):
            if len(row) > n_cols:
                raise ValueError(
                    f"block table for sequence {r} holds {len(row)} pages "
                    f"but only {n_cols} columns were requested")
        bt = [row + [SCRATCH_PAGE] * (n_cols - len(row)) for row in bt]
        return jnp.asarray(bt, jnp.int32)
