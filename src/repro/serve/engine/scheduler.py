"""Continuous-batching scheduler over the paged serving state.

Scheduling model (one `step()` = one engine iteration):

  1. **Lifecycle sweep** — injected faults (cancel/expiry chaos from an
     attached `FaultPlan`) and per-request deadlines are applied at the
     step boundary: a cancelled or expired request leaves whatever phase
     it is in with its pages and register slot scrubbed and returned.
  2. **Admission** — two policies, selected at construction:

     * `"optimistic"` (default): admit when the pages for the request's
       *prompt* plus a small headroom watermark fit next to the pages
       already committed. Utilization under bursty traffic is bounded by
       real demand, not by worst-case reservations — the trade is that
       the pool can genuinely exhaust mid-decode, which preemption
       (below) recovers from.
     * `"reserve"`: the safety baseline — admit only when
       `pages_for(prompt + max_new)` worst-case pages fit, so a running
       sequence can never hit an out-of-pages fault. Utilization caps
       exactly when traffic is heaviest (the pool fills with pages
       nobody has written yet).

     Committed pages are tracked as a running total (`_committed_total`,
     updated at admit/growth/finish/preempt/cancel), so admission is
     O(queue), not O(queue · active). Backoff-waiting replays are
     skipped; otherwise admission blocks head-of-line for fairness.

     With the **prefix cache** enabled (`prefix_cache=True`, kv-only
     specs), admission additionally matches the request's token stream
     against the radix tree (`radix.RadixCache`): fully-matched pages
     are incref'd straight into the block table, a partial-page match is
     recovered by copying that page (COW) into a private one, and
     `n_cached` starts at the hit length so chunked prefill begins at
     the divergence offset. Finished requests donate their page-aligned
     prefix back to the tree at release (under an LRU page budget)
     instead of scrubbing it; under page pressure the scheduler evicts
     cached prefixes (`_reclaim`) before preempting any live sequence.
  3. **Decode** — every generating sequence advances one token in a
     single batched `forward_chunk` call with per-slot fill positions,
     block-table rows, and register slot indices, padded to `max_seqs`
     rows so the jit cache shape is fixed. Before the dispatch, page
     growth runs under the preemption guard (below).
  4. **Chunked prefill** — the rest of the per-step token budget goes to
     the head-of-line prompt, `prefill_chunk` tokens at a time, chunks
     padded to the next power of two. A *replay* (preempted request)
     prefills `prompt + generated` through exactly the same path.

**Preemption / replay contract.** When page growth would exhaust the
allocator (really, or via an injected fault), the scheduler preempts a
victim — the active page-holding request with the fewest generated
tokens, latest-admitted breaking ties — releasing its pages and slot
through the same scrub path `release()` uses, and re-queues it at the
front with exponential step backoff. Replay recomputes the victim by
prefilling `prompt + generated` (all host-known — no swap traffic) and
must reproduce the *identical* continuation: greedy decoding is
deterministic, and sampling keys are derived per `(rid, position)` from
the engine seed (`_row_keys`), never from a global step key, so a
replayed sampled continuation is bit-identical no matter how the
interleaving changed. A request preempted more than `max_preemptions`
times fails terminally (`failed="preempted..."`) instead of livelocking.

**Swap-vs-replay cost rule.** With a host swap tier attached
(`swap_host_mb`, kv-only specs), `_handle_exhaustion` chooses per
victim: swap-out parks the victim's exclusively-held pages in host
memory (round-trip bytes = `2 · pages · page_bytes`) while replay
re-prefills `len(prompt + generated)` tokens — under the default
`"cost"` policy the victim swaps when the bytes are no more than
`swap_break_even_bytes_per_token` per replayed token (quantized int4
KV pages shrink the byte side 4-8x, tipping long sequences toward
swap), bounded by the host budget; `"always"`/`"never"` force either
arm. A swapped victim waits in the queue like a replay (front
insertion, exponential backoff, headroom waived) but re-admits by
swapping its pages back in — block-table row patched in place, zero
recomputed tokens, bit-identical continuation. Swap transfers can fail
(injected `SwapFault` or a genuinely full allocator at swap-in): the
engine retries with exponential backoff up to `swap_max_retries`, then
degrades the request to recompute-by-replay (counted
`engine.swap.fallbacks`, and from there the normal preemption bound
applies). Swap-outs do NOT count against `max_preemptions` — the
livelock bound protects against repeated *recompute* work, and a swap
round-trip loses none.

**Graceful degradation rails.** `drain()` stops admission (never-
admitted queued requests terminate `cancelled`), finishes all in-flight
work — including parked replays and swapped-out residents — then
asserts balanced books and zero non-scratch residency on every tier.
A non-finite max-logit in any fused sampling dispatch (a poisoned
adapter output: NaN/Inf) terminates only the poisoned rows with
`outcome="failed"` (counter `engine.requests.poisoned`) instead of
sampling garbage into the stream; and a raising `on_token` callback is
caught per-callback (`engine.stream.callback_errors`), dropped, and
never blocks delivery to other streams.

**Stall detection.** If nothing is active and an admission-eligible
request still cannot be admitted, no future step can make progress; the
scheduler raises `EngineStalledError` naming who is blocked and on how
many pages instead of spinning forever. Optimistic `submit()` rejects
up front prompts whose pages can never fit beside the headroom.

Telemetry: every engine counter lives in a `serve.telemetry`
`MetricsRegistry` (`self.metrics`), including the robustness families —
`engine.preemptions`, `engine.requests.cancelled/expired/failed`,
`engine.replayed_prefill_tokens`, `engine.dispatch.faults`, and the
live/peak page-utilization gauges. An optional `Tracer` records request
lifecycles and per-dispatch wall times, and optional `QualityProbes`
sample rotation-quality stats; both stay bit-path-neutral.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.serve.telemetry.metrics import MetricsRegistry
from repro.serve.telemetry.quality import QualityProbes
from repro.serve.telemetry.trace import PID_REQUESTS, Tracer

from .adapter import ServableModel
from .faults import DispatchFault, FaultPlan, SwapFault
from .pages import PagedKVCache, pages_for
from .radix import RadixCache


class EngineStalledError(RuntimeError):
    """Admission can never proceed: nothing is active and an eligible
    queued request still does not fit. Raised with a per-request
    diagnosis instead of letting `run()` spin forever."""


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _row_keys(base, rids, positions):
    """Replay-stable sampling keys: one PRNG key per batch row, derived
    from the `(rid, position)` pair — never from a global step key — so
    the token sampled at a given position of a given request is the same
    no matter which step, batch slot, or replay attempt produces it."""
    def one(r, p):
        return jax.random.fold_in(jax.random.fold_in(base, r), p)

    return jax.vmap(one)(rids, positions)


@functools.partial(jax.jit, static_argnames=("filtered",))
def _sample_tokens(keys, logits, temps, top_ks, top_ps, *, filtered=True):
    """One fused device call: greedy rows where temp == 0; elsewhere
    categorical over logits/temp restricted to the top-k tokens (k == 0
    disables) and then the nucleus — the smallest set whose probability
    mass reaches top_p (top_p >= 1 disables). Each row samples with its
    own `(rid, position)`-derived key (`keys` [B]), so stochastic rows
    are replay-stable. `filtered=False` (static — the scheduler knows
    host-side when every row has filtering off) skips the two full-vocab
    sorts so pure-greedy/temperature batches keep their pre-top-k/p
    cost."""
    v = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1)
    scaled = logits / jnp.where(temps > 0, temps, 1.0)[:, None]
    if filtered:
        desc = jnp.sort(scaled, axis=-1)[:, ::-1]
        kth = jnp.take_along_axis(desc,
                                  jnp.clip(top_ks - 1, 0, v - 1)[:, None],
                                  axis=-1)
        keep = (top_ks <= 0)[:, None] | (scaled >= kth)
        scaled = jnp.where(keep, scaled, -jnp.inf)
        probs = jax.nn.softmax(scaled, axis=-1)
        sp = jnp.sort(probs, axis=-1)[:, ::-1]
        cum = jnp.cumsum(sp, axis=-1)
        # a sorted token enters the nucleus while the mass before it is < p
        keep_sorted = ((cum - sp) < top_ps[:, None]) \
            | (top_ps >= 1.0)[:, None]
        thresh = jnp.min(jnp.where(keep_sorted, sp, jnp.inf), axis=-1)
        scaled = jnp.where(probs >= thresh[:, None], scaled, -jnp.inf)
    sampled = jax.vmap(
        lambda k, lg: jax.random.categorical(k, lg))(keys, scaled)
    return jnp.where(temps > 0, sampled, greedy)


@dataclasses.dataclass
class SamplingParams:
    """Per-request sampling: temperature 0 → greedy argmax. `top_k` > 0
    restricts sampling to the k most likely tokens, `top_p` < 1 to the
    nucleus; `stop` is a tuple of token-id sequences that end generation
    early (the matched suffix is kept in `generated`)."""
    temperature: float = 0.0
    max_new: int = 8
    top_k: int = 0
    top_p: float = 1.0
    stop: tuple = ()


@dataclasses.dataclass
class EngineRequest:
    rid: int
    prompt: list[int]
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    deadline_s: float | None = None  # TTL from submit, step-boundary checked
    generated: list[int] = dataclasses.field(default_factory=list)
    # per generated token: float32 logits row (only when record_logits)
    step_logits: list[np.ndarray] = dataclasses.field(default_factory=list)
    stop_hit: bool = False     # a stop sequence ended generation early
    # --- terminal lifecycle outcomes (at most one is ever set) ---
    cancelled: bool = False    # cancel(rid) took it out
    expired: bool = False      # deadline / injected TTL fired
    failed: str | None = None  # terminal failure, e.g. preemption limit
    # --- engine-internal state ---
    n_cached: int = 0          # KV rows already written for this sequence
    n_streamed: int = 0        # generated tokens already sent to on_token
    next_token: int | None = None
    n_preempted: int = 0       # times this request lost its pages
    admit_seq: int = -1        # monotonic admission order (victim pick)
    not_before_step: int = 0   # replay backoff: earliest re-admission step
    swapped: bool = False      # pages parked in the host tier (queued)
    n_swapped: int = 0         # times this request swapped out
    swap_retries: int = 0      # failed swap-in attempts since swap-out
    t_submit: float | None = None   # perf_counter at submit (telemetry)
    t_admit: float | None = None    # perf_counter at admission

    @property
    def done(self) -> bool:
        return (self.stop_hit or self.cancelled or self.expired
                or self.failed is not None
                or len(self.generated) >= self.sampling.max_new)

    @property
    def outcome(self) -> str | None:
        """Why the request ended: "length" | "stop" | "cancelled" |
        "expired" | "failed", or None while still in flight."""
        if self.cancelled:
            return "cancelled"
        if self.expired:
            return "expired"
        if self.failed is not None:
            return "failed"
        if self.stop_hit:
            return "stop"
        if len(self.generated) >= self.sampling.max_new:
            return "length"
        return None


class ServeEngine:
    """Paged-KV continuous-batching engine over any `ServableModel`."""

    def __init__(self, adapter: ServableModel, *, n_pages: int,
                 page_size: int = 16, max_seqs: int = 4,
                 prefill_chunk: int = 8, token_budget: int | None = None,
                 seed: int = 0, record_logits: bool = False,
                 admission: str = "optimistic",
                 headroom_pages: int | None = None,
                 max_preemptions: int = 3,
                 max_context: int | None = None,
                 deadline_s: float | None = None,
                 prefix_cache: bool = False,
                 prefix_cache_pages: int | None = None,
                 swap_host_mb: float | None = None,
                 swap_policy: str = "cost",
                 swap_max_retries: int = 3,
                 swap_break_even_bytes_per_token: float = 4096.0,
                 faults: FaultPlan | None = None,
                 tracer: Tracer | None = None,
                 quality_probes: QualityProbes | None = None):
        if admission not in ("optimistic", "reserve"):
            raise ValueError(f"admission must be 'optimistic' or 'reserve', "
                             f"got {admission!r}")
        if swap_policy not in ("never", "cost", "always"):
            raise ValueError(f"swap_policy must be 'never', 'cost', or "
                             f"'always', got {swap_policy!r}")
        self.adapter = adapter
        self.spec = adapter.state_spec
        self.max_seqs = max_seqs
        self.prefill_chunk = prefill_chunk
        self.token_budget = token_budget or max(max_seqs, prefill_chunk)
        self.record_logits = record_logits
        self.admission = admission
        self.max_preemptions = max_preemptions
        self.default_deadline_s = deadline_s
        self.faults = faults
        # one register slot per concurrent sequence (+ the scratch slot):
        # admission is bounded by max_seqs, so slots can never run out
        # before sequence slots do
        n_slots = max_seqs + 1
        self.kv = PagedKVCache(adapter.init_state(n_pages, page_size,
                                                  n_slots),
                               n_pages, page_size, n_slots=n_slots)
        cap = self.kv.allocator.capacity
        # headroom watermark: pages optimistic admission keeps free for
        # decode growth of the already-running batch (waived for replay
        # re-admission — a replay's requirement is already its real
        # footprint, and waiving it keeps replays always admittable)
        self.headroom_pages = min(max_seqs, cap // 4) \
            if headroom_pages is None else headroom_pages
        # context window: explicit, else the pool bound for kv specs
        # (register-only state never grows, so there is no implied bound)
        self.max_context = max_context if max_context is not None \
            else (cap * page_size if self.spec.kv else None)
        # prefix-sharing radix cache: kv-only specs (register/SSM state is
        # position-dependent — see StateSpec.prefix_shareable)
        if prefix_cache and not self.spec.prefix_shareable:
            raise ValueError(
                f"adapter {adapter.name!r} carries register state: SSM "
                "state is position-dependent, so the prefix cache cannot "
                "serve this spec")
        self.prefix_cache = RadixCache(self.kv, prefix_cache_pages) \
            if prefix_cache else None
        # host swap tier: a byte budget for parking preemption victims'
        # KV pages instead of recomputing them (kv-only specs — register
        # state is fixed-size slot-resident and never paged out)
        self.swap_policy = swap_policy if swap_host_mb else "never"
        self.swap_max_retries = swap_max_retries
        self.swap_break_even_bytes_per_token = swap_break_even_bytes_per_token
        if swap_host_mb and swap_policy != "never":
            if self.spec.register:
                raise ValueError(
                    f"adapter {adapter.name!r} carries register state: "
                    "fixed-size SSM slots are not paged, so the host swap "
                    "tier cannot serve this spec (recompute-by-replay "
                    "still covers it)")
            self.kv.attach_host_pool(swap_host_mb)
        self._draining = False
        self.queue: list[EngineRequest] = []
        self._callbacks: dict[int, Any] = {}   # rid → on_token streaming cb
        self.prefilling: list[EngineRequest] = []
        self.decoding: list[EngineRequest] = []
        self._committed: dict[int, int] = {}   # rid → committed page count
        self._committed_total = 0              # == sum(_committed.values())
        self._terminal: list[EngineRequest] = []   # drained by step()
        self._step_index = 0                   # never reset (faults key on it)
        self._admit_seq = 0
        self._base_key = jax.random.PRNGKey(seed)
        # jit cache for the fused phase dispatches, keyed on the kernels
        # flag (mirrors QuantizedDenseLM._jitted)
        self._jit_cache: dict = {}
        # telemetry: the registry owns every counter the old plain-int
        # attributes held (read-only property views keep the old names
        # alive); tracer and quality probes are opt-in and bit-path-
        # neutral. Page-walk accounting semantics are unchanged:
        # `engine.pages_walked` counts what the ragged early-exit
        # actually walks (ceil(len/page_size) live columns per sequence),
        # `engine.pages_walked_dense` what the pre-flash-decode kernel
        # walked (every padded batch row × every table column).
        self.metrics = MetricsRegistry()
        self.tracer = tracer
        self.quality_probes = quality_probes
        if quality_probes is not None:
            if not getattr(adapter, "supports_quality_probes", False):
                raise ValueError(
                    f"adapter {adapter.name!r} does not support quality "
                    "probes (integer path only)")
            quality_probes.bind(self.metrics)
        self._register_metrics()

    # ------------------------------------------------------------------
    # bookkeeping
    # ------------------------------------------------------------------

    @property
    def active(self) -> list[EngineRequest]:
        return self.prefilling + self.decoding

    def submit(self, req: EngineRequest,
               on_token: Callable[[int, int], None] | None = None):
        """Queue a request. `on_token(rid, token)`, when given, streams
        every generated token at the step boundary that produced it —
        after the step's device work and bookkeeping, so the callback can
        never perturb engine state mid-phase. Replays never re-deliver: a
        preempted request resumes streaming where it left off (its
        recomputed tokens are bit-identical, so nothing is retracted)."""
        if self._draining:
            raise RuntimeError(
                "engine is draining: new requests are not accepted")
        if not req.prompt:
            raise ValueError("empty prompt")
        if req.sampling.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if req.sampling.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 disables)")
        if not 0.0 < req.sampling.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")
        if any(len(seq) == 0 for seq in req.sampling.stop):
            raise ValueError("stop sequences must be non-empty")
        if req.n_cached or req.generated or req.done:
            raise ValueError(f"request {req.rid} carries stale engine "
                             "state; submit a fresh EngineRequest")
        if any(req.rid == r.rid for r in self.queue + self.active):
            raise ValueError(f"rid {req.rid} already queued or active")
        total = len(req.prompt) + req.sampling.max_new
        if self.max_context is not None and total > self.max_context:
            raise ValueError(
                f"request {req.rid}: prompt ({len(req.prompt)} tokens) + "
                f"max_new ({req.sampling.max_new}) exceeds the model "
                f"context window ({self.max_context} tokens)")
        if self.spec.kv:
            worst = pages_for(total, self.kv.page_size)
            cap = self.kv.allocator.capacity
            if worst > cap:
                raise ValueError(
                    f"request {req.rid} needs {worst} pages; pool capacity "
                    f"is {cap}")
            if self.admission == "optimistic" \
                    and pages_for(len(req.prompt), self.kv.page_size) \
                    + self.headroom_pages > cap:
                raise ValueError(
                    f"request {req.rid}: prompt pages + headroom "
                    f"({self.headroom_pages}) exceed pool capacity {cap} — "
                    "it could never be admitted (shrink the prompt or the "
                    "headroom watermark)")
        if req.deadline_s is None:
            req.deadline_s = self.default_deadline_s
        req.t_submit = time.perf_counter()
        self.queue.append(req)
        if on_token is not None:
            self._callbacks[req.rid] = on_token
        self.metrics.counter("engine.requests.submitted").inc()
        if self.tracer:
            self.tracer.begin("request", pid=PID_REQUESTS, tid=req.rid,
                              args={"prompt_tokens": len(req.prompt),
                                    "max_new": req.sampling.max_new})
            self.tracer.begin("queued", pid=PID_REQUESTS, tid=req.rid)

    def _stream(self, req: EngineRequest) -> list[int]:
        """The token stream prefill must cache: the prompt, plus — for a
        preempted request being replayed — every already-generated token
        (all host-known, so recovery needs no swap traffic)."""
        return req.prompt + req.generated

    def _pages_needed(self, req: EngineRequest) -> int:
        """KV pages admission requires for this request (0 for
        register-only models — their state never grows): the worst case
        under `"reserve"`, the prefill stream's pages under
        `"optimistic"` (growth is backed by preemption)."""
        if not self.spec.kv:
            return 0
        if req.swapped:
            # a swapped-out request re-admits by allocating device pages
            # for exactly its host-resident entries — its retained shared
            # pages never left the device and are already committed
            return sum(1 for e in self.kv.tables[req.rid]
                       if not isinstance(e, int))
        if self.admission == "reserve":
            return pages_for(len(req.prompt) + req.sampling.max_new,
                             self.kv.page_size)
        return pages_for(len(self._stream(req)), self.kv.page_size)

    def _admit(self):
        cap = self.kv.allocator.capacity
        i = 0
        while i < len(self.queue) and len(self.active) < self.max_seqs:
            req = self.queue[i]
            if req.not_before_step > self._step_index:
                i += 1               # replay backoff: try later entries
                continue
            need = self._pages_needed(req)
            headroom = self.headroom_pages \
                if self.admission == "optimistic" \
                and not (req.n_preempted or req.swapped) else 0
            if self._committed_total + need + headroom > cap:
                self.metrics.counter("engine.admission.blocked").inc()
                return           # head-of-line blocks until pages free up
            if req.swapped:
                if not self._try_swap_in(req):
                    # retry scheduled (backoff), degraded to replay, or
                    # terminally failed — either way queue[i] now either
                    # skips on not_before_step or is a different request
                    continue
                self.queue.remove(req)
                req.admit_seq = self._admit_seq
                self._admit_seq += 1
                # a mid-prefill victim resumes prefill at its preserved
                # n_cached; a decode victim rejoins the batched decode
                # (its sampled-but-uncached next_token rides along)
                phase = "decode" if req.next_token is not None \
                    else "prefill"
                (self.decoding if phase == "decode"
                 else self.prefilling).append(req)
                self.metrics.counter("engine.requests.admitted").inc()
                if self.tracer:
                    self.tracer.end("queued", pid=PID_REQUESTS, tid=req.rid)
                    self.tracer.instant("swapped_in", pid=PID_REQUESTS,
                                        tid=req.rid)
                    self.tracer.begin(phase, pid=PID_REQUESTS, tid=req.rid)
                continue
            self.queue.pop(i)
            self.kv.open(req.rid)     # before committing: if this raises,
            self._committed[req.rid] = need   # no reservation leaks
            self._committed_total += need
            if self.prefix_cache is not None:
                self._attach_prefix(req)
            req.admit_seq = self._admit_seq
            self._admit_seq += 1
            self.prefilling.append(req)
            self.metrics.counter("engine.requests.admitted").inc()
            if req.t_admit is None:
                # client-visible queueing delay: time to *first* admission
                # (a replay's re-admission shows up in the preemption
                # counters and e2e latency, not here)
                req.t_admit = time.perf_counter()
                self.metrics.histogram("engine.admission.wait_s").observe(
                    max(req.t_admit - req.t_submit, 0.0))
            if self.tracer:
                self.tracer.end("queued", pid=PID_REQUESTS, tid=req.rid)
                self.tracer.begin("prefill", pid=PID_REQUESTS, tid=req.rid)
                if self.spec.register:
                    self.tracer.instant(
                        "alloc_slot", pid=PID_REQUESTS, tid=req.rid,
                        args={"slot": self.kv.slots[req.rid]})

    def _alloc_pages(self, n: int) -> list[int]:
        """Allocate `n` pages, evicting cached prefixes under pressure —
        the tree gives pages back before any live sequence is preempted."""
        try:
            return self.kv.allocator.alloc(n)
        except MemoryError:
            if self.prefix_cache is None or not self.prefix_cache.evict(n):
                raise
            return self.kv.allocator.alloc(n)

    def _attach_prefix(self, req: EngineRequest):
        """Seed a just-admitted request's block table from the radix
        tree: incref the longest fully-matched page run, and when the
        match extends into a page partially (or the last-token clamp cuts
        one short), copy that page (COW) so the request can write into
        its private copy. `n_cached` starts at the hit length, so chunked
        prefill begins at the divergence offset."""
        stream = self._stream(req)
        pages, cow = self.prefix_cache.match(stream)
        ps = self.kv.page_size
        # the final stream position must always be recomputed: its logits
        # seed the next sampled token, and prefill is the only phase that
        # produces them
        hit = min(len(pages) * ps + (cow[1] if cow else 0), len(stream) - 1)
        m = self.metrics
        if hit <= 0:
            m.counter("engine.prefix.misses").inc()
            return
        n_full, extra = divmod(hit, ps)
        shared = pages[:n_full]
        src = (pages[n_full] if n_full < len(pages) else cow[0]) \
            if extra else None
        alloc = self.kv.allocator
        alloc.incref(shared)      # our references; also pins them against
        dst = None                # the eviction _alloc_pages may trigger
        if src is not None:
            alloc.incref([src])   # pin the COW source too
            try:
                dst = self._alloc_pages(1)[0]
            except MemoryError:
                # no room for a private copy — fall back to the full-page
                # hit (deref can never scrub: the tree still holds src)
                self.kv.deref([src])
                hit, src = n_full * ps, None
                if hit == 0:
                    m.counter("engine.prefix.misses").inc()
                    return
        table = self.kv.tables[req.rid]
        table.extend(shared)
        if src is not None:
            self.kv.cow_copy(src, dst)
            self.kv.deref([src])          # unpin; our copy carries on
            table.append(dst)
            m.counter("engine.prefix.cow_copies").inc()
        req.n_cached = hit
        m.counter("engine.prefix.hits").inc()
        m.counter("engine.prefix.hit_tokens").inc(hit)
        if self.tracer:
            self.tracer.instant("prefix_hit", pid=PID_REQUESTS, tid=req.rid,
                                args={"tokens": hit, "cow": src is not None})

    def _release(self, req: EngineRequest, adopted: int = 0):
        """Return an admitted request's pages/slot and its commitment.
        The first `adopted` table entries' references were consumed by
        the radix tree (see `_finish`) and are skipped."""
        self.kv.release(req.rid, adopted=adopted)
        self._committed_total -= self._committed.pop(req.rid)

    def _finish(self, req: EngineRequest):
        adopted = 0
        if self.prefix_cache is not None:
            # donate the finished stream's full pages to the tree: insert
            # consumes our reference on every page passed (adopting new
            # branches, dereffing duplicates of already-cached ones), so
            # release skips exactly that many table entries
            full = req.n_cached // self.kv.page_size
            if full:
                stream = self._stream(req)
                table = self.kv.tables[req.rid]
                self.prefix_cache.insert(stream[:full * self.kv.page_size],
                                         table[:full])
                adopted = full
        self._release(req, adopted=adopted)
        m = self.metrics
        m.counter("engine.requests.finished").inc()
        if req.stop_hit:
            m.counter("engine.requests.stop_hits").inc()
        if req.t_submit is not None:
            m.histogram("engine.request.e2e_s").observe(
                max(time.perf_counter() - req.t_submit, 0.0))
        if self.tracer:
            self.tracer.end("decode", pid=PID_REQUESTS, tid=req.rid)
            self.tracer.end("request", pid=PID_REQUESTS, tid=req.rid,
                            args={"generated": len(req.generated),
                                  "stop_hit": req.stop_hit})

    # ------------------------------------------------------------------
    # lifecycle: cancel / expire / preempt
    # ------------------------------------------------------------------

    def _phase_of(self, req: EngineRequest) -> str:
        if req in self.queue:
            return "queued"
        if req in self.prefilling:
            return "prefill"
        if req in self.decoding:
            return "decode"
        raise ValueError(f"rid {req.rid} is not queued or active")

    def _by_rid(self, rid: int) -> EngineRequest:
        for r in self.queue + self.active:
            if r.rid == rid:
                return r
        raise ValueError(f"rid {rid} is not queued or active")

    def _terminate(self, req: EngineRequest, outcome: str):
        """Take `req` out of whatever phase it is in: pages and slot
        scrubbed + released (admitted requests), books rebalanced, the
        terminal flag set, and the request queued for return from the
        current/next `step()`."""
        phase = self._phase_of(req)
        if phase == "queued":
            self.queue.remove(req)
            if req.swapped:
                # a swapped-out request parked in the queue still holds
                # host slots and (possibly) retained shared device pages
                self._release(req)
                req.swapped = False
        else:
            (self.prefilling if phase == "prefill"
             else self.decoding).remove(req)
            self._release(req)
        if outcome == "cancelled":
            req.cancelled = True
        elif outcome == "expired":
            req.expired = True
        # "failed" requests carry their reason in req.failed already
        self.metrics.counter(f"engine.requests.{outcome}").inc()
        if self.tracer:
            self.tracer.end(phase, pid=PID_REQUESTS, tid=req.rid)
            self.tracer.end("request", pid=PID_REQUESTS, tid=req.rid,
                            args={"outcome": outcome,
                                  "generated": len(req.generated)})
        self._terminal.append(req)

    def cancel(self, rid: int) -> EngineRequest:
        """Cancel a queued or mid-flight request: its pages and register
        slot are scrubbed and returned, books stay balanced, and the
        request (marked `cancelled`) is also returned from the next
        `step()`/`run()`."""
        req = self._by_rid(rid)
        self._terminate(req, "cancelled")
        return req

    def _expire_deadlines(self):
        now = time.perf_counter()
        for req in self.queue + self.active:
            if req.deadline_s is not None and req.t_submit is not None \
                    and now - req.t_submit > req.deadline_s:
                self._terminate(req, "expired")

    def _apply_faults(self):
        if self.faults is None:
            return
        live = sorted(r.rid for r in self.queue + self.active)
        for rid in self.faults.cancels_due(self._step_index, live):
            self.cancel(rid)
        live = sorted(r.rid for r in self.queue + self.active)
        for rid in self.faults.expiries_due(self._step_index, live):
            self._terminate(self._by_rid(rid), "expired")

    def _maybe_dispatch_fault(self, phase: str):
        if self.faults is None:
            return
        kind = self.faults.take_dispatch_fault(self._step_index)
        if kind == "delay":
            self.metrics.counter("engine.dispatch.faults").inc()
            time.sleep(self.faults.dispatch_delay_s)
        elif kind == "fail":
            raise DispatchFault(
                f"injected dispatch failure at step {self._step_index} "
                f"({phase})")

    def _preempt(self, req: EngineRequest):
        """Victimize an active request: scrub + release its pages (and
        slot) through the normal release path, then either re-queue it
        at the front as a replay (prefill of prompt + generated, with
        exponential step backoff) or — past `max_preemptions` — fail it
        terminally instead of livelocking."""
        phase = self._phase_of(req)
        (self.prefilling if phase == "prefill"
         else self.decoding).remove(req)
        m = self.metrics
        m.counter("engine.preemptions").inc()
        # replayed_prefill_tokens is charged by the replay's prefill for
        # what it *actually* recomputes — not here for what was lost: a
        # victim whose prefix is still resident in the radix tree gets
        # most of these rows back as pointer updates, and the shared
        # pages released below are unpinned, never scrubbed
        self._release(req)
        req.n_preempted += 1
        req.n_cached = 0
        req.next_token = None
        if self.tracer:
            self.tracer.end(phase, pid=PID_REQUESTS, tid=req.rid)
            self.tracer.instant("preempted", pid=PID_REQUESTS, tid=req.rid,
                                args={"n_preempted": req.n_preempted})
        if req.n_preempted > self.max_preemptions:
            req.failed = (f"preempted {req.n_preempted} times "
                          f"(max_preemptions={self.max_preemptions})")
            self.metrics.counter("engine.requests.failed").inc()
            if self.tracer:
                self.tracer.end("request", pid=PID_REQUESTS, tid=req.rid,
                                args={"outcome": "failed",
                                      "generated": len(req.generated)})
            self._terminal.append(req)
        else:
            req.not_before_step = \
                self._step_index + 2 ** (req.n_preempted - 1)
            if self.tracer:
                self.tracer.begin("queued", pid=PID_REQUESTS, tid=req.rid)
            self.queue.insert(0, req)

    def _should_swap(self, victim: EngineRequest) -> bool:
        """The swap-vs-replay cost rule: park the victim's exclusive
        pages in the host tier when (a) a tier exists with room for
        them, (b) there is anything exclusive to move at all (a victim
        whose pages are all radix-shared frees nothing by swapping), and
        (c) the policy's byte-vs-token arithmetic favors it: round-trip
        bytes (out now, in at re-admission) vs the tokens a replay would
        re-prefill, scaled by the configured break-even traffic per
        recomputed token. Quantized int4/int8 KV pages shrink the byte
        side 4-8x — exactly what tips long sequences toward swap."""
        host = self.kv.host_pool
        if host is None or self.swap_policy == "never":
            return False
        pages = self.kv.swap_eligible_pages(victim.rid)
        if not pages or len(pages) > host.n_free:
            return False
        if self.swap_policy == "always":
            return True
        move_bytes = 2 * len(pages) * self.kv.page_bytes
        replay_tokens = len(self._stream(victim))
        return move_bytes \
            <= replay_tokens * self.swap_break_even_bytes_per_token

    def _swap_out(self, req: EngineRequest):
        """Swap the victim's exclusive pages to the host tier instead of
        scrubbing them: the device copies free for the starving grower,
        and the victim re-queues at the front — like a replay, but its
        re-admission is a swap-in (zero recomputed tokens) rather than a
        re-prefill. Raises `SwapFault` (injected) before any mutation,
        letting `_handle_exhaustion` fall back to the replay arm.
        Swap-outs do not count against `max_preemptions`: that bound
        protects against repeated recompute work, and a swap round-trip
        loses none."""
        if self.faults is not None \
                and self.faults.take_swap_fault(self._step_index):
            raise SwapFault(
                f"injected swap-out failure at step {self._step_index}")
        phase = self._phase_of(req)
        n, nbytes = self.kv.swap_out(req.rid)
        (self.prefilling if phase == "prefill"
         else self.decoding).remove(req)
        m = self.metrics
        m.counter("engine.swap.out").inc()
        m.counter("engine.swap.bytes").inc(nbytes)
        # commitment shrinks to what stays device-resident (the retained
        # shared pages); the host-resident entries re-commit at swap-in
        held = sum(1 for e in self.kv.tables[req.rid]
                   if isinstance(e, int))
        cur = self._committed[req.rid]
        self._committed[req.rid] = held
        self._committed_total += held - cur
        req.swapped = True
        req.n_swapped += 1
        req.swap_retries = 0
        req.not_before_step = \
            self._step_index + 2 ** min(req.n_swapped - 1, 5)
        if self.tracer:
            self.tracer.end(phase, pid=PID_REQUESTS, tid=req.rid)
            self.tracer.instant("swapped_out", pid=PID_REQUESTS,
                                tid=req.rid,
                                args={"pages": n, "bytes": nbytes})
            self.tracer.begin("queued", pid=PID_REQUESTS, tid=req.rid)
        self.queue.insert(0, req)

    def _try_swap_in(self, req: EngineRequest) -> bool:
        """Re-admission transfer for a swapped-out request: allocate
        device pages (evicting cached prefixes under pressure), copy the
        host pages back, patch the block table in place. On an injected
        `SwapFault` or a genuine allocation failure the attempt retries
        with exponential backoff up to `swap_max_retries`, then degrades
        to recompute-by-replay."""
        m = self.metrics
        try:
            if self.faults is not None \
                    and self.faults.take_swap_fault(self._step_index):
                raise SwapFault(
                    f"injected swap-in failure at step {self._step_index}")
            n, nbytes = self.kv.swap_in(req.rid, self._alloc_pages)
        except (SwapFault, MemoryError) as e:
            req.swap_retries += 1
            if req.swap_retries > self.swap_max_retries:
                self._fallback_to_replay(req, why=str(e))
            else:
                m.counter("engine.swap.retries").inc()
                req.not_before_step = \
                    self._step_index + 2 ** (req.swap_retries - 1)
            return False
        m.counter("engine.swap.in").inc()
        m.counter("engine.swap.bytes").inc(nbytes)
        held = len(self.kv.tables[req.rid])
        cur = self._committed[req.rid]
        self._committed[req.rid] = held
        self._committed_total += held - cur
        req.swapped = False
        req.swap_retries = 0
        return True

    def _fallback_to_replay(self, req: EngineRequest, *, why: str):
        """Degrade a swapped-out queued request to PR 8 recompute-by-
        replay: drop its host copy and residual device references, reset
        the cached state, and let the normal replay admission path
        re-prefill it — bounded by `max_preemptions` like any
        preemption (the recompute bound applies the moment recompute
        work actually becomes necessary)."""
        m = self.metrics
        m.counter("engine.swap.fallbacks").inc()
        self._release(req)
        req.swapped = False
        req.swap_retries = 0
        req.n_cached = 0
        req.next_token = None
        req.n_preempted += 1
        m.counter("engine.preemptions").inc()
        if self.tracer:
            self.tracer.instant("swap_fallback", pid=PID_REQUESTS,
                                tid=req.rid, args={"why": why})
        if req.n_preempted > self.max_preemptions:
            req.failed = (f"swap-in abandoned ({why}); preempted "
                          f"{req.n_preempted} times "
                          f"(max_preemptions={self.max_preemptions})")
            self._terminate(req, "failed")
        else:
            req.not_before_step = self._step_index + 1

    def _reclaim(self):
        """Page pressure ladder: cached prefixes are speculative capacity,
        live sequences are real work — evict from the radix tree first
        and only preempt a victim when the tree has nothing unpinned left
        to give."""
        if self.prefix_cache is not None \
                and self.prefix_cache.evict(max(1, self.max_seqs)):
            return
        self._handle_exhaustion()

    def _handle_exhaustion(self):
        """The page pool exhausted mid-growth: pick the best victim —
        fewest generated tokens (least work lost), latest-admitted
        breaking ties — among active requests that actually hold pages,
        then either swap its exclusive pages to the host tier (when the
        cost rule says the bytes beat the replay) or preempt it for
        recompute-by-replay. An injected swap fault falls back to the
        replay arm for the same victim."""
        holders = [r for r in self.active if self.kv.tables.get(r.rid)]
        if not holders:
            # every held page belongs to swapped-out queue entries
            # (retained shared pages) — or the books really are broken:
            # degrade one swapped request to a full replay, freeing its
            # residual references, and let the grower retry
            for r in self.queue:
                if r.swapped:
                    self._fallback_to_replay(
                        r, why="page pool exhausted with no active holder")
                    return
            alloc = self.kv.allocator
            raise EngineStalledError(
                "page pool exhausted but no active request holds pages — "
                f"allocator books are broken (capacity {alloc.capacity}, "
                f"free {alloc.n_free}, committed {self._committed_total})")
        victim = min(holders,
                     key=lambda r: (len(r.generated), -r.admit_seq))
        if self._should_swap(victim):
            try:
                self._swap_out(victim)
                return
            except SwapFault:
                self.metrics.counter("engine.swap.fallbacks").inc()
        self._preempt(victim)

    def _check_stalled(self):
        """Raise a diagnosable error when head-of-line demand can never
        be satisfied: nothing is active (so no pages will ever free up)
        and an admission-eligible request is still blocked."""
        if self.active or not self.queue:
            return
        eligible = [r for r in self.queue
                    if r.not_before_step <= self._step_index]
        if not eligible:
            return        # every entry is in replay backoff; steps advance
        alloc = self.kv.allocator
        who = "; ".join(
            f"rid {r.rid} needs {self._pages_needed(r)} pages"
            for r in eligible)
        raise EngineStalledError(
            "scheduler stalled: no active sequences and admission cannot "
            f"proceed (capacity {alloc.capacity}, free {alloc.n_free}, "
            f"committed {self._committed_total}, headroom "
            f"{self.headroom_pages if self.admission == 'optimistic' else 0}"
            f"); blocked: {who}")

    def _fused(self, name: str, impl, variant=None):
        """One fused device dispatch per phase: forward (page writes +
        table walk inside) → sample trace into a single jit'd call, so
        per-step host overhead stays flat as the model grows. The pool is
        donated — a pool sized to fill HBM must not need a second copy
        live across the in-place page update. Compiled once per (phase,
        kernels-enabled, variant) triple with the flag re-pinned inside
        the traced body, so `use_kernels(...)` scopes keep selecting the
        path they request instead of replaying the first-traced one;
        `variant` keys host-known static choices (e.g. whether any row
        needs top-k/p filtering)."""
        key = (name, kops.kernels_enabled(), variant)
        fn = self._jit_cache.get(key)
        if fn is None:
            enabled = key[1]

            def wrapped(*args):
                with kops.use_kernels(enabled):
                    return impl(*args)

            fn = self._jit_cache[key] = jax.jit(wrapped, donate_argnums=(0,))
        return fn

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------

    def _register_metrics(self):
        """Pre-create every engine instrument so a snapshot is schema-
        complete (`serve.telemetry.schema`) even before any traffic."""
        m = self.metrics
        for name in ("engine.steps", "engine.prefill_tokens",
                     "engine.decode_tokens", "engine.generated_tokens",
                     "engine.pages_walked", "engine.pages_walked_dense",
                     "engine.requests.submitted", "engine.requests.admitted",
                     "engine.requests.finished", "engine.requests.stop_hits",
                     "engine.requests.cancelled", "engine.requests.expired",
                     "engine.requests.failed", "engine.preemptions",
                     "engine.replayed_prefill_tokens",
                     "engine.dispatch.faults", "engine.admission.blocked",
                     "engine.prefix.hits", "engine.prefix.misses",
                     "engine.prefix.hit_tokens", "engine.prefix.cow_copies",
                     "engine.prefix.inserted_pages",
                     "engine.prefix.evicted_pages",
                     "engine.swap.out", "engine.swap.in",
                     "engine.swap.bytes", "engine.swap.retries",
                     "engine.swap.fallbacks", "engine.requests.poisoned",
                     "engine.stream.callback_errors"):
            m.counter(name)
        for name in ("engine.step.wall_s", "engine.step.budget_utilization",
                     "engine.decode.batch_occupancy",
                     "engine.decode.token_latency_s",
                     "engine.admission.wait_s", "engine.request.e2e_s",
                     "engine.prefill.chunk_tokens"):
            m.histogram(name)
        self._update_gauges()

    def _update_gauges(self):
        """Refresh the level gauges from the live bookkeeping."""
        m = self.metrics
        alloc = self.kv.allocator
        m.gauge("engine.pages.capacity").set(alloc.capacity)
        m.gauge("engine.pages.in_use").set(alloc.in_use)
        m.gauge("engine.pages.peak_in_use").set(alloc.peak_in_use)
        m.gauge("engine.pages.utilization").set(
            alloc.in_use / max(alloc.capacity, 1))
        m.gauge("engine.pages.utilization_peak").set(
            alloc.peak_in_use / max(alloc.capacity, 1))
        m.gauge("engine.pages.reserved").set(self._committed_total)
        m.gauge("engine.pages.scrubbed").set(self.kv.pages_scrubbed)
        m.gauge("engine.queue.depth").set(len(self.queue))
        m.gauge("engine.batch.decoding").set(len(self.decoding))
        m.gauge("engine.batch.prefilling").set(len(self.prefilling))
        m.gauge("engine.pages.shared").set(alloc.n_shared)
        tree = self.prefix_cache
        m.gauge("engine.prefix.tree_pages").set(
            tree.n_pages if tree is not None else 0)
        m.gauge("engine.prefix.tree_nodes").set(
            tree.n_nodes if tree is not None else 0)
        if tree is not None:
            # the tree counts its own insert/evict traffic; mirror it as
            # monotonic counters (same pattern as the kernel dispatch
            # tallies in metrics_snapshot)
            for name, n in (("engine.prefix.inserted_pages",
                             tree.inserted_pages),
                            ("engine.prefix.evicted_pages",
                             tree.evicted_pages)):
                c = m.counter(name)
                if n > c.value:
                    c.value = n
        # host-tier occupancy: always emitted (zeros when no pool) so the
        # snapshot shape is policy-independent
        hp = self.kv.host_pool
        pb = self.kv.page_bytes
        m.gauge("engine.swap.host_pages").set(hp.in_use if hp else 0)
        m.gauge("engine.swap.host_pages_capacity").set(
            hp.capacity if hp else 0)
        m.gauge("engine.swap.host_bytes").set(hp.in_use * pb if hp else 0)
        m.gauge("engine.swap.host_budget_bytes").set(
            hp.capacity * pb if hp else 0)
        regs = self.kv.registers
        if regs is not None:
            m.gauge("engine.register_slots.capacity").set(regs.capacity)
            m.gauge("engine.register_slots.in_use").set(regs.in_use)
            m.gauge("engine.register_slots.peak_in_use").set(
                regs.peak_in_use)
            m.gauge("engine.register_slots.scrubbed").set(
                self.kv.slots_scrubbed)

    def metrics_snapshot(self) -> dict:
        """Schema-versioned registry export (the shape
        `serve.telemetry.schema.validate_snapshot` checks): refresh the
        level gauges, mirror the kernel layer's per-entry-point dispatch
        tallies, and snapshot."""
        self._update_gauges()
        for (entry, path), n in kops.dispatch_counts().items():
            c = self.metrics.counter(f"kernels.dispatch.{entry}.{path}")
            if n > c.value:
                c.value = n   # mirror of an external monotonic count
        return self.metrics.snapshot()

    def reset_metrics(self):
        """Start a fresh measurement window: zero the registry in place
        (names and held instrument references survive), restart the
        allocator high-water marks and scrub totals, and clear the
        kernel dispatch tallies and the probe sampling phase. Engine
        *state* (queues, caches, PRNG seed, step index) is untouched —
        this is the boundary the benches put between warm-up and the
        timed run."""
        self.metrics.reset()
        self.kv.allocator.reset_peak()
        if self.kv.registers is not None:
            self.kv.registers.reset_peak()
        self.kv.pages_scrubbed = 0
        self.kv.slots_scrubbed = 0
        if self.prefix_cache is not None:
            # cached *contents* survive the window boundary (they are
            # state, not measurement); only the traffic stats restart
            self.prefix_cache.inserted_pages = 0
            self.prefix_cache.evicted_pages = 0
        kops.reset_dispatch_counts()
        if self.quality_probes is not None:
            self.quality_probes.reset()
        self._update_gauges()

    def check_books(self):
        """Assert the accounting invariants the chaos tests lean on:
        the running committed total matches the per-rid map, every
        committed rid is active, and allocator free + in-use cover the
        capacity exactly. Cheap enough to call after every step."""
        assert self._committed_total == sum(self._committed.values()), \
            (self._committed_total, self._committed)
        active = {r.rid for r in self.active}
        swapped = {r.rid for r in self.queue if r.swapped}
        assert not (active & swapped), (active, swapped)
        assert set(self._committed) == active | swapped \
            == set(self.kv.tables), \
            (set(self._committed), active, swapped, set(self.kv.tables))
        # a swapped rid's commitment covers exactly its device-resident
        # (retained shared) entries — host residency is not pool demand
        for r in self.queue:
            if r.swapped:
                assert self._committed[r.rid] == sum(
                    1 for e in self.kv.tables[r.rid] if isinstance(e, int))
        # quiescent between ops: no page may be stuck mid-transfer
        assert not self.kv._inflight, self.kv._inflight
        # host-tier books: slots in use == host-resident table entries,
        # each referenced exactly once (host pages are never shared)
        host_refs = [e.slot for t in self.kv.tables.values()
                     for e in t if not isinstance(e, int)]
        hp = self.kv.host_pool
        if hp is not None:
            assert hp.in_use == len(host_refs) == len(set(host_refs)), \
                (hp.in_use, host_refs)
        else:
            assert not host_refs, host_refs
        alloc = self.kv.allocator
        # sharing-aware: a page may appear in several tables *and* the
        # radix tree, but occupies the pool once — and its refcount must
        # equal exactly that multiplicity (tree membership counts once)
        counts: dict[int, int] = {}
        for t in self.kv.tables.values():
            for p in t:
                if isinstance(p, int):
                    counts[p] = counts.get(p, 0) + 1
        if self.prefix_cache is not None:
            tree_pages = self.prefix_cache.held_pages()
            assert len(tree_pages) == self.prefix_cache.n_pages, \
                (len(tree_pages), self.prefix_cache.n_pages)
            for p in tree_pages:
                counts[p] = counts.get(p, 0) + 1
        assert alloc.in_use == len(counts), (alloc.in_use, len(counts))
        for p, c in counts.items():
            assert alloc.refcount(p) == c, (p, alloc.refcount(p), c)
        assert alloc.n_free + alloc.in_use == alloc.capacity
        if self.kv.registers is not None:
            assert self.kv.registers.in_use == len(self.kv.slots)

    def _ensure(self, rid: int, n_tokens: int):
        """`kv.ensure` plus the optimistic growth-commit update, the
        fault-injection hook, and an instant trace event when the growth
        actually allocated pages."""
        table = self.kv.tables[rid]
        need = pages_for(n_tokens, self.kv.page_size) - len(table)
        if need > 0 and self.faults is not None \
                and any(self.kv.tables.get(r.rid) for r in self.active) \
                and self.faults.take_exhaustion(self._step_index):
            # only inject once a victim exists — a real allocator can't
            # exhaust while zero pages are held
            raise MemoryError(
                f"injected page exhaustion at step {self._step_index}")
        if self.tracer is None:
            self.kv.ensure(rid, n_tokens)
        else:
            before = self.kv.allocator.n_free
            self.kv.ensure(rid, n_tokens)
            got = before - self.kv.allocator.n_free
            if got:
                self.tracer.instant("alloc_pages", pid=PID_REQUESTS, tid=rid,
                                    args={"pages": got})
        # commitment follows real growth (no-op under "reserve", whose
        # worst-case commitment always covers the table)
        held = len(table)
        cur = self._committed[rid]
        if held > cur:
            self._committed[rid] = held
            self._committed_total += held - cur

    # -- back-compat counter views (the registry owns the numbers) -----

    @property
    def n_steps(self) -> int:
        return self.metrics.counter("engine.steps").value

    @property
    def n_prefill_tokens(self) -> int:
        return self.metrics.counter("engine.prefill_tokens").value

    @property
    def n_decode_tokens(self) -> int:
        return self.metrics.counter("engine.decode_tokens").value

    @property
    def pages_walked(self) -> int:
        return self.metrics.counter("engine.pages_walked").value

    @property
    def pages_walked_dense(self) -> int:
        return self.metrics.counter("engine.pages_walked_dense").value

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------

    @staticmethod
    def _check_stop(req: EngineRequest):
        for seq in req.sampling.stop:
            n = len(seq)
            if len(req.generated) >= n and req.generated[-n:] == list(seq):
                req.stop_hit = True
                return

    @staticmethod
    def _wants_filtering(batch) -> bool:
        return any(r.sampling.top_k > 0 or r.sampling.top_p < 1.0
                   for r in batch)

    def _grow_decode(self):
        """Grow every decoding sequence's table by one position,
        preempting victims until growth fits (ensure is idempotent, so
        the retry loop re-runs cheaply after each preemption)."""
        if not self.spec.kv:
            return
        while True:
            try:
                for req in list(self.decoding):
                    self._ensure(req.rid, req.n_cached + 1)
                return
            except MemoryError:
                self._reclaim()

    def _decode_impl(self, state, params, base, bt, reg, tokens, fill, lens,
                     rids, temps, top_ks, top_ps, *, filtered, probe=False):
        # block-table-native: the forward writes each new KV row into its
        # page and attends by walking `bt` — no gathered slab exists.
        # `lens` are the true per-slot context lengths (0 for padded
        # rows): the kernel's ragged early-exit walks only each
        # sequence's live pages instead of every table column. `reg` is
        # each row's register slot (scratch for padded rows) for models
        # whose spec carries fixed-size state. Sampling keys derive from
        # (rid, lens) — lens IS the sampled token's stream position — so
        # a replayed request resamples identically. The probe variant
        # (its own compiled executable via the jit-cache variant key)
        # additionally returns the barrier-isolated per-layer quality
        # stats — same dispatch shapes, same sampling keys.
        if probe:
            logits, state, stats = self.adapter.forward_chunk(
                params, tokens, state, fill, bt, lens, reg, probe=True)
        else:
            logits, state = self.adapter.forward_chunk(params, tokens, state,
                                                       fill, bt, lens, reg)
        lg = logits[:, 0].astype(jnp.float32)
        keys = _row_keys(base, rids, lens)
        toks = _sample_tokens(keys, lg, temps, top_ks, top_ps,
                              filtered=filtered)
        # per-row max logit: the host-side non-finite sentinel reads it
        # to flag poisoned rows (NaN/Inf adapter output) without pulling
        # the full logits matrix off device
        mx = jnp.max(lg, axis=-1)
        if probe:
            return state, lg, toks, mx, stats
        return state, lg, toks, mx

    def _decode_once(self) -> list[EngineRequest]:
        batch = self.decoding
        b = self.max_seqs
        m = self.metrics
        rids = [r.rid for r in batch] + [None] * (b - len(batch))
        new_lens = [r.n_cached + 1 for r in batch]
        if self.spec.kv:
            n_cols = _next_pow2(max(
                pages_for(r.n_cached + 1, self.kv.page_size) for r in batch))
            bt = self.kv.block_table_array(rids, n_cols)
            m.counter("engine.pages_walked").inc(
                sum(pages_for(n, self.kv.page_size) for n in new_lens))
            m.counter("engine.pages_walked_dense").inc(b * n_cols)
        else:
            bt = None
        reg = self.kv.register_index_array(rids) if self.spec.register \
            else None
        tokens = jnp.asarray(
            [[r.next_token] for r in batch] + [[0]] * (b - len(batch)),
            jnp.int32)
        fill = jnp.asarray([r.n_cached for r in batch]
                           + [0] * (b - len(batch)), jnp.int32)
        lens = jnp.asarray(new_lens + [0] * (b - len(batch)), jnp.int32)
        rid_rows = jnp.asarray([r.rid for r in batch]
                               + [0] * (b - len(batch)), jnp.int32)

        temps = jnp.asarray([r.sampling.temperature for r in batch]
                            + [0.0] * (b - len(batch)), jnp.float32)
        top_ks = jnp.asarray([r.sampling.top_k for r in batch]
                             + [0] * (b - len(batch)), jnp.int32)
        top_ps = jnp.asarray([r.sampling.top_p for r in batch]
                             + [1.0] * (b - len(batch)), jnp.float32)
        filtered = self._wants_filtering(batch)
        probe = (self.quality_probes is not None
                 and self.quality_probes.should_probe())
        m.histogram("engine.decode.batch_occupancy").observe(
            len(batch) / self.max_seqs)
        tr = self.tracer
        ts0 = tr.ts() if tr else 0.0
        out = self._fused(
            "decode",
            functools.partial(self._decode_impl, filtered=filtered,
                              probe=probe),
            variant=(filtered, probe))(
            self.kv.state, self.adapter.params, self._base_key, bt, reg,
            tokens, fill, lens, rid_rows, temps, top_ks, top_ps)
        if probe:
            self.kv.state, logits, toks, mx, stats = out
        else:
            (self.kv.state, logits, toks, mx), stats = out, None
        if tr:
            jax.block_until_ready((self.kv.state, toks))
            tr.complete("dispatch.decode", ts0, tr.ts() - ts0,
                        args={"rows": len(batch), "probe": probe})
        if stats is not None:
            self.quality_probes.record(stats)
        toks = np.asarray(toks)
        finite = np.isfinite(np.asarray(mx))
        finished = []
        for i, req in enumerate(list(batch)):
            if not finite[i]:
                # poisoned adapter output (NaN/Inf logits): terminate
                # only this row — its sampled token is garbage and must
                # not enter the stream; other rows are independent
                req.failed = (f"non-finite logits at stream position "
                              f"{req.n_cached} (poisoned model output)")
                m.counter("engine.requests.poisoned").inc()
                self._terminate(req, "failed")   # returned via _terminal
                continue
            req.n_cached += 1
            req.generated.append(int(toks[i]))
            req.next_token = int(toks[i])
            if self.record_logits:
                req.step_logits.append(np.asarray(logits[i], np.float32))
            m.counter("engine.decode_tokens").inc()
            m.counter("engine.generated_tokens").inc()
            self._check_stop(req)
            if req.done:
                self.decoding.remove(req)
                self._finish(req)
                finished.append(req)
        return finished

    # ------------------------------------------------------------------
    # chunked prefill
    # ------------------------------------------------------------------

    def _prefill_impl(self, state, params, base, bt, reg, tokens, start,
                      last, lens, rids, temp, top_k, top_p, *, filtered):
        # padded tail rows are computed too (their queries may attend the
        # garbage keys the same forward wrote for earlier padding tokens,
        # so their outputs are meaningless and discarded); their in-page
        # writes land on the scratch page or on not-yet-valid slots that
        # are rewritten before the causal mask ever exposes them. `lens`
        # is the true cached length after this chunk (start + real): the
        # kernel's early-exit trims the walk to the live pages, which
        # also stops the padded tail queries from touching columns past
        # them (their outputs are discarded either way), and — via
        # valid_len = lens - start inside the model — keeps the padded
        # tail out of register-kind (SSM) carried state, whose update is
        # a recurrence rather than a masked read. `lens` doubles as the
        # sampled token's stream position for the (rid, position) key.
        logits, state = self.adapter.forward_chunk(params, tokens, state,
                                                   start, bt, lens, reg)
        lg = jax.lax.dynamic_index_in_dim(logits, last, axis=1,
                                          keepdims=False)[0]
        lg = lg.astype(jnp.float32)
        keys = _row_keys(base, rids, lens)
        tok = _sample_tokens(keys, lg[None], temp, top_k, top_p,
                             filtered=filtered)[0]
        # max logit of the sampled row, for the non-finite sentinel
        return state, lg, tok, jnp.max(lg)

    def _prefill_once(self, budget: int) -> tuple[int, list[EngineRequest]]:
        """Advance the head-of-line prefill by up to `budget` tokens of
        its stream (the prompt, plus already-generated tokens when
        replaying a preempted request); returns (tokens consumed,
        requests finished)."""
        req = self.prefilling[0]
        stream = self._stream(req)
        start = req.n_cached
        m = self.metrics
        real = min(self.prefill_chunk, budget, len(stream) - start)
        padded = _next_pow2(real)
        if self.spec.kv:
            while True:
                try:
                    self._ensure(req.rid, start + real)
                    break
                except MemoryError:
                    self._reclaim()
                    if req not in self.prefilling:
                        return 0, []    # the head itself was preempted
            n_cols = _next_pow2(pages_for(start + padded, self.kv.page_size))
            bt = self.kv.block_table_array([req.rid], n_cols)
            m.counter("engine.pages_walked").inc(
                pages_for(start + real, self.kv.page_size))
            m.counter("engine.pages_walked_dense").inc(n_cols)
        else:
            bt = None
        reg = self.kv.register_index_array([req.rid]) if self.spec.register \
            else None
        self._maybe_dispatch_fault("prefill")

        # every device-side shape depends only on (padded, n_cols), both
        # powers of two, so prefill compiles a bounded set of variants;
        # `last` (= real - 1) rides along as a traced scalar
        chunk = stream[start:start + real] + [0] * (padded - real)
        filtered = self._wants_filtering([req])
        tr = self.tracer
        ts0 = tr.ts() if tr else 0.0
        self.kv.state, last, tok, mx = self._fused(
            "prefill",
            functools.partial(self._prefill_impl, filtered=filtered),
            variant=filtered)(
            self.kv.state, self.adapter.params, self._base_key, bt, reg,
            jnp.asarray([chunk], jnp.int32), jnp.asarray(start, jnp.int32),
            jnp.asarray(real - 1, jnp.int32),
            jnp.asarray([start + real], jnp.int32),
            jnp.asarray([req.rid], jnp.int32),
            jnp.asarray([req.sampling.temperature], jnp.float32),
            jnp.asarray([req.sampling.top_k], jnp.int32),
            jnp.asarray([req.sampling.top_p], jnp.float32))
        if tr:
            jax.block_until_ready((self.kv.state, tok))
            tr.complete("dispatch.prefill", ts0, tr.ts() - ts0,
                        args={"rid": req.rid, "tokens": real,
                              "padded": padded})
        if not np.isfinite(float(mx)):
            # poisoned adapter output mid-prefill: the chunk's logits are
            # garbage, so the request cannot continue — terminate it
            # alone (the single-sequence dispatch touched no other state)
            req.failed = (f"non-finite logits in prefill at stream "
                          f"position {start + real - 1} "
                          f"(poisoned model output)")
            m.counter("engine.requests.poisoned").inc()
            self._terminate(req, "failed")
            return real, []

        req.n_cached = start + real
        m.counter("engine.prefill_tokens").inc(real)
        if req.n_preempted > 0:
            # replay cost = rows actually recomputed (a prefix-tree hit
            # at re-admission already skipped the resident ones)
            m.counter("engine.replayed_prefill_tokens").inc(real)
        m.histogram("engine.prefill.chunk_tokens").observe(real)
        finished = []
        if req.n_cached == len(stream):
            # stream fully cached: the fused call already sampled the
            # next token from the last real position's logits (for a
            # replay, this continues the original sequence exactly — the
            # (rid, position) key is the one the undisturbed decode used)
            self.prefilling.remove(req)
            req.generated.append(int(tok))
            req.next_token = int(tok)
            if self.record_logits:
                req.step_logits.append(np.asarray(last, np.float32))
            m.counter("engine.generated_tokens").inc()
            if tr:
                tr.end("prefill", pid=PID_REQUESTS, tid=req.rid)
                tr.begin("decode", pid=PID_REQUESTS, tid=req.rid)
            self._check_stop(req)
            if req.done:
                self._finish(req)
                finished.append(req)
            else:
                self.decoding.append(req)
        return real, finished

    # ------------------------------------------------------------------
    # driver
    # ------------------------------------------------------------------

    def step(self) -> list[EngineRequest]:
        """One engine iteration; returns every request that reached a
        terminal state during it (completed, cancelled, expired, or
        failed — check `req.outcome`)."""
        m = self.metrics
        t0 = time.perf_counter()
        gen0 = m.counter("engine.generated_tokens").value
        self._apply_faults()
        self._expire_deadlines()
        self._admit()
        self._check_stalled()
        finished = []
        budget = self.token_budget
        spent = 0
        if self.decoding:
            try:
                self._maybe_dispatch_fault("decode")
                self._grow_decode()
                if self.decoding:
                    budget -= len(self.decoding)
                    spent += len(self.decoding)
                    finished.extend(self._decode_once())
            except DispatchFault:
                m.counter("engine.dispatch.faults").inc()
        # `guard` bounds the zero-progress retries a preempted prefill
        # head can cause within one step (each retry strictly shrinks
        # the active set, so this terminates regardless)
        guard = len(self.prefilling) + 1
        while budget > 0 and self.prefilling and guard > 0:
            try:
                used, fin = self._prefill_once(budget)
            except DispatchFault:
                m.counter("engine.dispatch.faults").inc()
                break
            budget -= used
            spent += used
            finished.extend(fin)
            if used == 0:
                guard -= 1
        self._step_index += 1
        m.counter("engine.steps").inc()
        wall = time.perf_counter() - t0
        m.histogram("engine.step.wall_s").observe(wall)
        m.histogram("engine.step.budget_utilization").observe(
            spent / self.token_budget)
        # each token generated this step inherits the step's wall time
        # (np.asarray on the sampled tokens already forced the device
        # sync, so the wall is real even without tracing)
        lat = m.histogram("engine.decode.token_latency_s")
        for _ in range(m.counter("engine.generated_tokens").value - gen0):
            lat.observe(wall)
        self._update_gauges()
        finished.extend(self._terminal)
        self._terminal.clear()
        self._flush_streams(finished)
        return finished

    def _flush_streams(self, finished: list[EngineRequest]):
        """Step-boundary streaming: deliver every not-yet-streamed
        generated token to its request's `on_token` callback. Runs after
        all device work and bookkeeping for the step, so callbacks
        observe a consistent engine and cannot perturb the step that
        produced their tokens. Terminal requests' callbacks are dropped
        after their final flush. A *raising* callback is caught per
        callback — counted (`engine.stream.callback_errors`) and dropped
        so one broken consumer can never abort delivery to the other
        streams or propagate out of `step()` after bookkeeping."""
        if not self._callbacks:
            return
        for req in self.active + finished:
            cb = self._callbacks.get(req.rid)
            if cb is None:
                continue
            while req.n_streamed < len(req.generated):
                tok = req.generated[req.n_streamed]
                req.n_streamed += 1
                try:
                    cb(req.rid, tok)
                except Exception:
                    self.metrics.counter(
                        "engine.stream.callback_errors").inc()
                    self._callbacks.pop(req.rid, None)
                    break
        for req in finished:
            self._callbacks.pop(req.rid, None)

    def run(self) -> list[EngineRequest]:
        done = []
        while self.queue or self.active:
            done.extend(self.step())
        done.extend(self._terminal)   # cancels issued between steps
        self._terminal.clear()
        self._flush_streams(done)
        return done

    def drain(self) -> list[EngineRequest]:
        """Graceful shutdown: stop admitting — queued requests that were
        never admitted terminate `cancelled` — finish every piece of
        in-flight work (active sequences, parked replays, swapped-out
        residents: all of it represents admitted work the engine owes an
        answer for), then assert balanced books and zero non-scratch
        residency on every tier. Returns the requests that reached a
        terminal state during the drain. The engine stays draining
        afterwards: further `submit()` calls are rejected."""
        self._draining = True
        for req in list(self.queue):
            if req.t_admit is None:
                # never admitted — no partial work to honor
                self.cancel(req.rid)
        done = []
        while self.queue or self.active:
            done.extend(self.step())
        done.extend(self._terminal)
        self._terminal.clear()
        self._flush_streams(done)
        if self.prefix_cache is not None:
            self.prefix_cache.clear()
        self.check_books()
        alloc = self.kv.allocator
        assert alloc.in_use == 0 and alloc.n_free == alloc.capacity, \
            f"device pages leaked: {alloc.in_use} still in use"
        assert not self.kv.tables and not self.kv.slots, \
            (self.kv.tables, self.kv.slots)
        assert not self._committed and self._committed_total == 0, \
            (self._committed, self._committed_total)
        hp = self.kv.host_pool
        assert hp is None or hp.in_use == 0, \
            f"host tier leaked: {hp.in_use} slots still in use"
        regs = self.kv.registers
        assert regs is None or regs.in_use == 0, \
            f"register slots leaked: {regs.in_use} still in use"
        return done
