"""Continuous-batching scheduler over the paged serving state.

Scheduling model (one `step()` = one engine iteration):

  1. **Admission** — requests are admitted whenever a sequence slot is free
     and the page allocator can cover the request's worst case
     (`pages_for(prompt + max_new)` KV pages when the model's state spec
     has a kv part, plus one register slot when it has a register part);
     reservation-based admission means a running sequence can never hit an
     out-of-pages fault mid-decode. Register slots are sized to `max_seqs`,
     so a free sequence slot implies a free register slot.
  2. **Decode** — every generating sequence advances one token in a single
     batched `forward_chunk` call with per-slot fill positions (vector
     cache index), its block-table rows, and its register slot index. The
     batch is padded to `max_seqs` rows pointing at the scratch page/slot,
     so batch shape — and hence the jit cache — is fixed.
  3. **Chunked prefill** — whatever remains of the per-step token budget
     goes to prompt processing, `prefill_chunk` tokens at a time through
     the same `forward_chunk` entry (causal within the chunk, scalar fill
     index), instead of the legacy one-token-per-step prompt drip. Chunks
     are padded to the next power of two so prefill shapes stay bounded;
     `seq_lengths` carries each row's true extent so SSM state carried
     across chunks ignores the padded tail.

The scheduler itself never branches on architecture: it reads the
adapter's `StateSpec` to know which index kinds to build. Dense/MoE runs
are pure kv (block tables only), pure SSMs are pure register (no tables,
no page walk), hybrids pass both. The kv phases stay block-table-native:
the state and block tables go straight into `forward_chunk`, which writes
each new KV row into its page and walks the table inside the
paged-attention kernel — the scheduler never materialises a gathered slab
(`pages.gather_pages` / `pages.scatter_*_rows` survive only as the test
oracle).

Sampling threads one PRNG key per engine step (split per request batch), so
`temperature > 0` is genuinely stochastic — per-request `SamplingParams`
pick greedy vs temperature sampling row by row, with optional top-k /
nucleus (top-p) filtering fused into the same `_sample_tokens` dispatch and
per-request stop sequences cutting generation short.

Telemetry: every engine counter lives in a `serve.telemetry`
`MetricsRegistry` (`self.metrics`; the old plain-int attributes survive
as read-only views), exported via `metrics_snapshot()` and reset along a
measurement-window boundary by `reset_metrics()`. An optional `Tracer`
records request-lifecycle spans and per-fused-dispatch wall times, and
optional `QualityProbes` sample the rotation-quality stats every K
decode dispatches through a probe variant of the fused forward. Both are
off by default and bit-path-neutral: they never change dispatch shapes,
argument values, or PRNG key consumption (regression-tested).
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.serve.telemetry.metrics import MetricsRegistry
from repro.serve.telemetry.quality import QualityProbes
from repro.serve.telemetry.trace import PID_REQUESTS, Tracer

from .adapter import ServableModel
from .pages import PagedKVCache, pages_for


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@functools.partial(jax.jit, static_argnames=("filtered",))
def _sample_tokens(key, logits, temps, top_ks, top_ps, *, filtered=True):
    """One fused device call: greedy rows where temp == 0; elsewhere
    categorical over logits/temp restricted to the top-k tokens (k == 0
    disables) and then the nucleus — the smallest set whose probability
    mass reaches top_p (top_p >= 1 disables). `filtered=False` (static —
    the scheduler knows host-side when every row has filtering off) skips
    the two full-vocab sorts so pure-greedy/temperature batches keep
    their pre-top-k/p cost."""
    v = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1)
    scaled = logits / jnp.where(temps > 0, temps, 1.0)[:, None]
    if filtered:
        desc = jnp.sort(scaled, axis=-1)[:, ::-1]
        kth = jnp.take_along_axis(desc,
                                  jnp.clip(top_ks - 1, 0, v - 1)[:, None],
                                  axis=-1)
        keep = (top_ks <= 0)[:, None] | (scaled >= kth)
        scaled = jnp.where(keep, scaled, -jnp.inf)
        probs = jax.nn.softmax(scaled, axis=-1)
        sp = jnp.sort(probs, axis=-1)[:, ::-1]
        cum = jnp.cumsum(sp, axis=-1)
        # a sorted token enters the nucleus while the mass before it is < p
        keep_sorted = ((cum - sp) < top_ps[:, None]) \
            | (top_ps >= 1.0)[:, None]
        thresh = jnp.min(jnp.where(keep_sorted, sp, jnp.inf), axis=-1)
        scaled = jnp.where(probs >= thresh[:, None], scaled, -jnp.inf)
    sampled = jax.random.categorical(key, scaled, axis=-1)
    return jnp.where(temps > 0, sampled, greedy)


@dataclasses.dataclass
class SamplingParams:
    """Per-request sampling: temperature 0 → greedy argmax. `top_k` > 0
    restricts sampling to the k most likely tokens, `top_p` < 1 to the
    nucleus; `stop` is a tuple of token-id sequences that end generation
    early (the matched suffix is kept in `generated`)."""
    temperature: float = 0.0
    max_new: int = 8
    top_k: int = 0
    top_p: float = 1.0
    stop: tuple = ()


@dataclasses.dataclass
class EngineRequest:
    rid: int
    prompt: list[int]
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    generated: list[int] = dataclasses.field(default_factory=list)
    # per generated token: float32 logits row (only when record_logits)
    step_logits: list[np.ndarray] = dataclasses.field(default_factory=list)
    stop_hit: bool = False     # a stop sequence ended generation early
    # --- engine-internal state ---
    n_cached: int = 0          # KV rows already written for this sequence
    next_token: int | None = None
    t_submit: float | None = None   # perf_counter at submit (telemetry)
    t_admit: float | None = None    # perf_counter at admission

    @property
    def done(self) -> bool:
        return self.stop_hit or len(self.generated) >= self.sampling.max_new


class ServeEngine:
    """Paged-KV continuous-batching engine over any `ServableModel`."""

    def __init__(self, adapter: ServableModel, *, n_pages: int,
                 page_size: int = 16, max_seqs: int = 4,
                 prefill_chunk: int = 8, token_budget: int | None = None,
                 seed: int = 0, record_logits: bool = False,
                 tracer: Tracer | None = None,
                 quality_probes: QualityProbes | None = None):
        self.adapter = adapter
        self.spec = adapter.state_spec
        self.max_seqs = max_seqs
        self.prefill_chunk = prefill_chunk
        self.token_budget = token_budget or max(max_seqs, prefill_chunk)
        self.record_logits = record_logits
        # one register slot per concurrent sequence (+ the scratch slot):
        # admission is bounded by max_seqs, so slots can never run out
        # before sequence slots do
        n_slots = max_seqs + 1
        self.kv = PagedKVCache(adapter.init_state(n_pages, page_size,
                                                  n_slots),
                               n_pages, page_size, n_slots=n_slots)
        self.queue: list[EngineRequest] = []
        self.prefilling: list[EngineRequest] = []
        self.decoding: list[EngineRequest] = []
        self._committed: dict[int, int] = {}   # rid → reserved page count
        self._key = jax.random.PRNGKey(seed)
        # jit cache for the fused phase dispatches, keyed on the kernels
        # flag (mirrors QuantizedDenseLM._jitted)
        self._jit_cache: dict = {}
        # telemetry: the registry owns every counter the old plain-int
        # attributes held (read-only property views keep the old names
        # alive); tracer and quality probes are opt-in and bit-path-
        # neutral. Page-walk accounting semantics are unchanged:
        # `engine.pages_walked` counts what the ragged early-exit
        # actually walks (ceil(len/page_size) live columns per sequence),
        # `engine.pages_walked_dense` what the pre-flash-decode kernel
        # walked (every padded batch row × every table column).
        self.metrics = MetricsRegistry()
        self.tracer = tracer
        self.quality_probes = quality_probes
        if quality_probes is not None:
            if not getattr(adapter, "supports_quality_probes", False):
                raise ValueError(
                    f"adapter {adapter.name!r} does not support quality "
                    "probes (integer path only)")
            quality_probes.bind(self.metrics)
        self._register_metrics()

    # ------------------------------------------------------------------
    # bookkeeping
    # ------------------------------------------------------------------

    @property
    def active(self) -> list[EngineRequest]:
        return self.prefilling + self.decoding

    def submit(self, req: EngineRequest):
        if not req.prompt:
            raise ValueError("empty prompt")
        if req.sampling.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if req.sampling.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 disables)")
        if not 0.0 < req.sampling.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")
        if any(len(seq) == 0 for seq in req.sampling.stop):
            raise ValueError("stop sequences must be non-empty")
        if req.n_cached or req.generated:
            raise ValueError(f"request {req.rid} carries stale engine "
                             "state; submit a fresh EngineRequest")
        if any(req.rid == r.rid for r in self.queue + self.active):
            raise ValueError(f"rid {req.rid} already queued or active")
        need = self._pages_needed(req)
        if need > self.kv.allocator.capacity:
            raise ValueError(
                f"request {req.rid} needs {need} pages; pool capacity is "
                f"{self.kv.allocator.capacity}")
        req.t_submit = time.perf_counter()
        self.queue.append(req)
        self.metrics.counter("engine.requests.submitted").inc()
        if self.tracer:
            self.tracer.begin("request", pid=PID_REQUESTS, tid=req.rid,
                              args={"prompt_tokens": len(req.prompt),
                                    "max_new": req.sampling.max_new})
            self.tracer.begin("queued", pid=PID_REQUESTS, tid=req.rid)

    def _pages_needed(self, req: EngineRequest) -> int:
        """Worst-case KV pages this request reserves at admission (0 for
        register-only models — their state never grows)."""
        if not self.spec.kv:
            return 0
        return pages_for(len(req.prompt) + req.sampling.max_new,
                         self.kv.page_size)

    def _admit(self):
        while self.queue and len(self.active) < self.max_seqs:
            req = self.queue[0]
            need = self._pages_needed(req)
            if sum(self._committed.values()) + need \
                    > self.kv.allocator.capacity:
                self.metrics.counter("engine.admission.blocked").inc()
                return           # head-of-line blocks until pages free up
            self.queue.pop(0)
            self.kv.open(req.rid)     # before committing: if this raises,
            self._committed[req.rid] = need   # no reservation leaks
            self.prefilling.append(req)
            req.t_admit = time.perf_counter()
            self.metrics.counter("engine.requests.admitted").inc()
            self.metrics.histogram("engine.admission.wait_s").observe(
                max(req.t_admit - req.t_submit, 0.0))
            if self.tracer:
                self.tracer.end("queued", pid=PID_REQUESTS, tid=req.rid)
                self.tracer.begin("prefill", pid=PID_REQUESTS, tid=req.rid)
                if self.spec.register:
                    self.tracer.instant(
                        "alloc_slot", pid=PID_REQUESTS, tid=req.rid,
                        args={"slot": self.kv.slots[req.rid]})

    def _finish(self, req: EngineRequest):
        self.kv.release(req.rid)
        del self._committed[req.rid]
        m = self.metrics
        m.counter("engine.requests.finished").inc()
        if req.stop_hit:
            m.counter("engine.requests.stop_hits").inc()
        if req.t_submit is not None:
            m.histogram("engine.request.e2e_s").observe(
                max(time.perf_counter() - req.t_submit, 0.0))
        if self.tracer:
            self.tracer.end("decode", pid=PID_REQUESTS, tid=req.rid)
            self.tracer.end("request", pid=PID_REQUESTS, tid=req.rid,
                            args={"generated": len(req.generated),
                                  "stop_hit": req.stop_hit})

    def _fused(self, name: str, impl, variant=None):
        """One fused device dispatch per phase: forward (page writes +
        table walk inside) → sample (plus the PRNG split) trace into a
        single jit'd call, so per-step host overhead stays flat as the
        model grows. The pool is donated — a pool sized to fill HBM must
        not need a second copy live across the in-place page update.
        Compiled once per (phase, kernels-enabled, variant) triple with
        the flag re-pinned inside the traced body, so `use_kernels(...)`
        scopes keep selecting the path they request instead of replaying
        the first-traced one; `variant` keys host-known static choices
        (e.g. whether any row needs top-k/p filtering)."""
        key = (name, kops.kernels_enabled(), variant)
        fn = self._jit_cache.get(key)
        if fn is None:
            enabled = key[1]

            def wrapped(*args):
                with kops.use_kernels(enabled):
                    return impl(*args)

            fn = self._jit_cache[key] = jax.jit(wrapped, donate_argnums=(0,))
        return fn

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------

    def _register_metrics(self):
        """Pre-create every engine instrument so a snapshot is schema-
        complete (`serve.telemetry.schema`) even before any traffic."""
        m = self.metrics
        for name in ("engine.steps", "engine.prefill_tokens",
                     "engine.decode_tokens", "engine.generated_tokens",
                     "engine.pages_walked", "engine.pages_walked_dense",
                     "engine.requests.submitted", "engine.requests.admitted",
                     "engine.requests.finished", "engine.requests.stop_hits",
                     "engine.admission.blocked"):
            m.counter(name)
        for name in ("engine.step.wall_s", "engine.step.budget_utilization",
                     "engine.decode.batch_occupancy",
                     "engine.decode.token_latency_s",
                     "engine.admission.wait_s", "engine.request.e2e_s",
                     "engine.prefill.chunk_tokens"):
            m.histogram(name)
        self._update_gauges()

    def _update_gauges(self):
        """Refresh the level gauges from the live bookkeeping."""
        m = self.metrics
        alloc = self.kv.allocator
        m.gauge("engine.pages.capacity").set(alloc.capacity)
        m.gauge("engine.pages.in_use").set(alloc.in_use)
        m.gauge("engine.pages.peak_in_use").set(alloc.peak_in_use)
        m.gauge("engine.pages.reserved").set(sum(self._committed.values()))
        m.gauge("engine.pages.scrubbed").set(self.kv.pages_scrubbed)
        m.gauge("engine.queue.depth").set(len(self.queue))
        m.gauge("engine.batch.decoding").set(len(self.decoding))
        m.gauge("engine.batch.prefilling").set(len(self.prefilling))
        regs = self.kv.registers
        if regs is not None:
            m.gauge("engine.register_slots.capacity").set(regs.capacity)
            m.gauge("engine.register_slots.in_use").set(regs.in_use)
            m.gauge("engine.register_slots.peak_in_use").set(
                regs.peak_in_use)
            m.gauge("engine.register_slots.scrubbed").set(
                self.kv.slots_scrubbed)

    def metrics_snapshot(self) -> dict:
        """Schema-versioned registry export (the shape
        `serve.telemetry.schema.validate_snapshot` checks): refresh the
        level gauges, mirror the kernel layer's per-entry-point dispatch
        tallies, and snapshot."""
        self._update_gauges()
        for (entry, path), n in kops.dispatch_counts().items():
            c = self.metrics.counter(f"kernels.dispatch.{entry}.{path}")
            if n > c.value:
                c.value = n   # mirror of an external monotonic count
        return self.metrics.snapshot()

    def reset_metrics(self):
        """Start a fresh measurement window: zero the registry in place
        (names and held instrument references survive), restart the
        allocator high-water marks and scrub totals, and clear the
        kernel dispatch tallies and the probe sampling phase. Engine
        *state* (queues, caches, PRNG key) is untouched — this is the
        boundary the benches put between warm-up and the timed run."""
        self.metrics.reset()
        self.kv.allocator.reset_peak()
        if self.kv.registers is not None:
            self.kv.registers.reset_peak()
        self.kv.pages_scrubbed = 0
        self.kv.slots_scrubbed = 0
        kops.reset_dispatch_counts()
        if self.quality_probes is not None:
            self.quality_probes.reset()
        self._update_gauges()

    def _ensure(self, rid: int, n_tokens: int):
        """`kv.ensure` plus an instant trace event when the growth
        actually allocated pages."""
        if self.tracer is None:
            self.kv.ensure(rid, n_tokens)
            return
        before = self.kv.allocator.n_free
        self.kv.ensure(rid, n_tokens)
        got = before - self.kv.allocator.n_free
        if got:
            self.tracer.instant("alloc_pages", pid=PID_REQUESTS, tid=rid,
                                args={"pages": got})

    # -- back-compat counter views (the registry owns the numbers) -----

    @property
    def n_steps(self) -> int:
        return self.metrics.counter("engine.steps").value

    @property
    def n_prefill_tokens(self) -> int:
        return self.metrics.counter("engine.prefill_tokens").value

    @property
    def n_decode_tokens(self) -> int:
        return self.metrics.counter("engine.decode_tokens").value

    @property
    def pages_walked(self) -> int:
        return self.metrics.counter("engine.pages_walked").value

    @property
    def pages_walked_dense(self) -> int:
        return self.metrics.counter("engine.pages_walked_dense").value

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------

    @staticmethod
    def _check_stop(req: EngineRequest):
        for seq in req.sampling.stop:
            n = len(seq)
            if len(req.generated) >= n and req.generated[-n:] == list(seq):
                req.stop_hit = True
                return

    @staticmethod
    def _wants_filtering(batch) -> bool:
        return any(r.sampling.top_k > 0 or r.sampling.top_p < 1.0
                   for r in batch)

    def _decode_impl(self, state, params, key, bt, reg, tokens, fill, lens,
                     temps, top_ks, top_ps, *, filtered, probe=False):
        # block-table-native: the forward writes each new KV row into its
        # page and attends by walking `bt` — no gathered slab exists.
        # `lens` are the true per-slot context lengths (0 for padded
        # rows): the kernel's ragged early-exit walks only each
        # sequence's live pages instead of every table column. `reg` is
        # each row's register slot (scratch for padded rows) for models
        # whose spec carries fixed-size state. The probe variant (its own
        # compiled executable via the jit-cache variant key) additionally
        # returns the barrier-isolated per-layer quality stats — same
        # dispatch shapes, same PRNG key consumption.
        if probe:
            logits, state, stats = self.adapter.forward_chunk(
                params, tokens, state, fill, bt, lens, reg, probe=True)
        else:
            logits, state = self.adapter.forward_chunk(params, tokens, state,
                                                       fill, bt, lens, reg)
        key, sub = jax.random.split(key)
        lg = logits[:, 0].astype(jnp.float32)
        toks = _sample_tokens(sub, lg, temps, top_ks, top_ps,
                              filtered=filtered)
        if probe:
            return state, key, lg, toks, stats
        return state, key, lg, toks

    def _decode_once(self) -> list[EngineRequest]:
        batch = self.decoding
        b = self.max_seqs
        m = self.metrics
        rids = [r.rid for r in batch] + [None] * (b - len(batch))
        new_lens = [r.n_cached + 1 for r in batch]
        if self.spec.kv:
            for req in batch:
                self._ensure(req.rid, req.n_cached + 1)
            n_cols = _next_pow2(max(
                pages_for(r.n_cached + 1, self.kv.page_size) for r in batch))
            bt = self.kv.block_table_array(rids, n_cols)
            m.counter("engine.pages_walked").inc(
                sum(pages_for(n, self.kv.page_size) for n in new_lens))
            m.counter("engine.pages_walked_dense").inc(b * n_cols)
        else:
            bt = None
        reg = self.kv.register_index_array(rids) if self.spec.register \
            else None
        tokens = jnp.asarray(
            [[r.next_token] for r in batch] + [[0]] * (b - len(batch)),
            jnp.int32)
        fill = jnp.asarray([r.n_cached for r in batch]
                           + [0] * (b - len(batch)), jnp.int32)
        lens = jnp.asarray(new_lens + [0] * (b - len(batch)), jnp.int32)

        temps = jnp.asarray([r.sampling.temperature for r in batch]
                            + [0.0] * (b - len(batch)), jnp.float32)
        top_ks = jnp.asarray([r.sampling.top_k for r in batch]
                             + [0] * (b - len(batch)), jnp.int32)
        top_ps = jnp.asarray([r.sampling.top_p for r in batch]
                             + [1.0] * (b - len(batch)), jnp.float32)
        filtered = self._wants_filtering(batch)
        probe = (self.quality_probes is not None
                 and self.quality_probes.should_probe())
        m.histogram("engine.decode.batch_occupancy").observe(
            len(batch) / self.max_seqs)
        tr = self.tracer
        ts0 = tr.ts() if tr else 0.0
        out = self._fused(
            "decode",
            functools.partial(self._decode_impl, filtered=filtered,
                              probe=probe),
            variant=(filtered, probe))(
            self.kv.state, self.adapter.params, self._key, bt, reg, tokens,
            fill, lens, temps, top_ks, top_ps)
        if probe:
            self.kv.state, self._key, logits, toks, stats = out
        else:
            (self.kv.state, self._key, logits, toks), stats = out, None
        if tr:
            jax.block_until_ready((self.kv.state, toks))
            tr.complete("dispatch.decode", ts0, tr.ts() - ts0,
                        args={"rows": len(batch), "probe": probe})
        if stats is not None:
            self.quality_probes.record(stats)
        toks = np.asarray(toks)
        finished = []
        for i, req in enumerate(list(batch)):
            req.n_cached += 1
            req.generated.append(int(toks[i]))
            req.next_token = int(toks[i])
            if self.record_logits:
                req.step_logits.append(np.asarray(logits[i], np.float32))
            m.counter("engine.decode_tokens").inc()
            m.counter("engine.generated_tokens").inc()
            self._check_stop(req)
            if req.done:
                self.decoding.remove(req)
                self._finish(req)
                finished.append(req)
        return finished

    # ------------------------------------------------------------------
    # chunked prefill
    # ------------------------------------------------------------------

    def _prefill_impl(self, state, params, key, bt, reg, tokens, start, last,
                      lens, temp, top_k, top_p, *, filtered):
        # padded tail rows are computed too (their queries may attend the
        # garbage keys the same forward wrote for earlier padding tokens,
        # so their outputs are meaningless and discarded); their in-page
        # writes land on the scratch page or on not-yet-valid slots that
        # are rewritten before the causal mask ever exposes them. `lens`
        # is the true cached length after this chunk (start + real): the
        # kernel's early-exit trims the walk to the live pages, which
        # also stops the padded tail queries from touching columns past
        # them (their outputs are discarded either way), and — via
        # valid_len = lens - start inside the model — keeps the padded
        # tail out of register-kind (SSM) carried state, whose update is
        # a recurrence rather than a masked read.
        logits, state = self.adapter.forward_chunk(params, tokens, state,
                                                   start, bt, lens, reg)
        key, sub = jax.random.split(key)
        lg = jax.lax.dynamic_index_in_dim(logits, last, axis=1,
                                          keepdims=False)[0]
        lg = lg.astype(jnp.float32)
        return state, key, lg, _sample_tokens(sub, lg[None], temp, top_k,
                                              top_p, filtered=filtered)[0]

    def _prefill_once(self, budget: int) -> tuple[int, list[EngineRequest]]:
        """Advance the head-of-line prefill by up to `budget` prompt
        tokens; returns (tokens consumed, requests finished)."""
        req = self.prefilling[0]
        start = req.n_cached
        m = self.metrics
        real = min(self.prefill_chunk, budget, len(req.prompt) - start)
        padded = _next_pow2(real)
        if self.spec.kv:
            self._ensure(req.rid, start + real)
            n_cols = _next_pow2(pages_for(start + padded, self.kv.page_size))
            bt = self.kv.block_table_array([req.rid], n_cols)
            m.counter("engine.pages_walked").inc(
                pages_for(start + real, self.kv.page_size))
            m.counter("engine.pages_walked_dense").inc(n_cols)
        else:
            bt = None
        reg = self.kv.register_index_array([req.rid]) if self.spec.register \
            else None

        # every device-side shape depends only on (padded, n_cols), both
        # powers of two, so prefill compiles a bounded set of variants;
        # `last` (= real - 1) rides along as a traced scalar
        chunk = req.prompt[start:start + real] + [0] * (padded - real)
        filtered = self._wants_filtering([req])
        tr = self.tracer
        ts0 = tr.ts() if tr else 0.0
        self.kv.state, self._key, last, tok = self._fused(
            "prefill",
            functools.partial(self._prefill_impl, filtered=filtered),
            variant=filtered)(
            self.kv.state, self.adapter.params, self._key, bt, reg,
            jnp.asarray([chunk], jnp.int32), jnp.asarray(start, jnp.int32),
            jnp.asarray(real - 1, jnp.int32),
            jnp.asarray([start + real], jnp.int32),
            jnp.asarray([req.sampling.temperature], jnp.float32),
            jnp.asarray([req.sampling.top_k], jnp.int32),
            jnp.asarray([req.sampling.top_p], jnp.float32))
        if tr:
            jax.block_until_ready((self.kv.state, tok))
            tr.complete("dispatch.prefill", ts0, tr.ts() - ts0,
                        args={"rid": req.rid, "tokens": real,
                              "padded": padded})

        req.n_cached = start + real
        m.counter("engine.prefill_tokens").inc(real)
        m.histogram("engine.prefill.chunk_tokens").observe(real)
        finished = []
        if req.n_cached == len(req.prompt):
            # prompt fully cached: the fused call already sampled the
            # first generated token from the last real position's logits
            self.prefilling.remove(req)
            req.generated.append(int(tok))
            req.next_token = int(tok)
            if self.record_logits:
                req.step_logits.append(np.asarray(last, np.float32))
            m.counter("engine.generated_tokens").inc()
            if tr:
                tr.end("prefill", pid=PID_REQUESTS, tid=req.rid)
                tr.begin("decode", pid=PID_REQUESTS, tid=req.rid)
            self._check_stop(req)
            if req.done:
                self._finish(req)
                finished.append(req)
            else:
                self.decoding.append(req)
        return real, finished

    # ------------------------------------------------------------------
    # driver
    # ------------------------------------------------------------------

    def step(self) -> list[EngineRequest]:
        """One engine iteration; returns requests that completed."""
        m = self.metrics
        t0 = time.perf_counter()
        gen0 = m.counter("engine.generated_tokens").value
        self._admit()
        finished = []
        budget = self.token_budget
        spent = 0
        if self.decoding:
            budget -= len(self.decoding)
            spent += len(self.decoding)
            finished.extend(self._decode_once())
        while budget > 0 and self.prefilling:
            used, fin = self._prefill_once(budget)
            budget -= used
            spent += used
            finished.extend(fin)
        m.counter("engine.steps").inc()
        wall = time.perf_counter() - t0
        m.histogram("engine.step.wall_s").observe(wall)
        m.histogram("engine.step.budget_utilization").observe(
            spent / self.token_budget)
        # each token generated this step inherits the step's wall time
        # (np.asarray on the sampled tokens already forced the device
        # sync, so the wall is real even without tracing)
        lat = m.histogram("engine.decode.token_latency_s")
        for _ in range(m.counter("engine.generated_tokens").value - gen0):
            lat.observe(wall)
        self._update_gauges()
        return finished

    def run(self) -> list[EngineRequest]:
        done = []
        while self.queue or self.active:
            done.extend(self.step())
        return done
