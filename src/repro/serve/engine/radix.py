"""Radix prefix cache: token-id-keyed tree over refcounted KV pages.

Production traffic is dominated by shared prefixes — system prompts,
few-shot headers, multi-turn chat histories — whose prefill the engine
used to recompute from scratch for every request. This module keeps the
KV pages of *finished* streams alive in a vLLM/SGLang-style radix tree
keyed on token ids, so a later request whose prompt shares a prefix is
admitted with those pages already in its block table and chunked prefill
starts at the divergence offset instead of position 0. The INT4 KV page
formats this repo serves make shared prefixes 4× denser in HBM, so the
deduplication compounds with the quantization win.

Design:

  * **Nodes own page-granular runs of the pool.** Every edge's token run
    is a whole number of pages (`len(node.tokens) == len(node.pages) ·
    page_size`) and only *full* pages are ever inserted — the tail rows
    of a stream that don't fill a page are released normally. Splits
    therefore happen at page boundaries; where two streams diverge
    *inside* a page, the tree keeps only the page-aligned common prefix.
  * **The tree is a holder like any sequence.** Inserted pages carry the
    tree's reference in `PageAllocator`'s refcounts; a matching request
    increfs them into its own block table, so a page is freed (and
    scrubbed) only when the tree *and* every sequence using it have let
    go. Pages in the tree are immutable: a sequence that needs to write
    into one first copies it (`PagedKVCache.cow_copy`) — see `match`.
  * **Matching is token-granular via copy-on-write.** `match` walks the
    tree for the longest fully-matched page run, then peeks one page
    further: if the next cached page agrees on a partial run of tokens,
    it is reported as a COW candidate — the scheduler copies it into a
    fresh page and resumes prefill mid-page, recovering the sub-page
    sharing the page-aligned storage cannot represent.
  * **LRU eviction under a page budget.** Each matched/inserted node is
    stamped with a monotonic clock; `evict` trims least-recently-used
    leaves first (truncating a leaf's page run from the tail, dropping
    the node when it empties), skipping pages still referenced by live
    sequences. Inserts that would exceed `max_pages` evict first and
    drop whatever still does not fit. The scheduler also calls `evict`
    under allocator pressure, so cached prefixes are reclaimed before
    any live sequence is preempted.

Register slots never appear here: SSM conv/SSD state is a
position-dependent running summary, not an addressable prefix, so the
engine only enables the cache for pure-kv state specs.
"""
from __future__ import annotations

from .pages import PagedKVCache


class RadixNode:
    """One edge of the tree: a page-aligned token run and its pages."""

    __slots__ = ("tokens", "pages", "children", "parent", "last_access")

    def __init__(self, tokens: list[int], pages: list[int],
                 parent: "RadixNode | None"):
        self.tokens = tokens        # len == len(pages) * page_size
        self.pages = pages
        self.children: dict[int, RadixNode] = {}  # keyed by first token
        self.parent = parent
        self.last_access = 0


def _common_prefix(a: list[int], b: list[int]) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


class RadixCache:
    """Token-id-keyed radix tree over one `PagedKVCache`'s page pool."""

    def __init__(self, kv: PagedKVCache, max_pages: int | None = None):
        if max_pages is not None and max_pages < 0:
            raise ValueError("max_pages must be >= 0 (None = unbounded)")
        self.kv = kv
        self.page_size = kv.page_size
        self.max_pages = max_pages
        self.root = RadixNode([], [], None)
        self._clock = 0
        self.n_pages = 0       # pages the tree currently holds a ref on
        # telemetry, mirrored into the engine's registry
        self.evicted_pages = 0
        self.inserted_pages = 0

    @property
    def n_nodes(self) -> int:
        def count(node: RadixNode) -> int:
            return 1 + sum(count(c) for c in node.children.values())
        return count(self.root) - 1    # root is not a real node

    def _touch(self, node: RadixNode):
        self._clock += 1
        node.last_access = self._clock

    # ------------------------------------------------------------------
    # match
    # ------------------------------------------------------------------

    def match(self, tokens: list[int]
              ) -> tuple[list[int], tuple[int, int] | None]:
        """Longest cached prefix of `tokens`.

        Returns `(pages, cow)`: the fully-matched pages (covering
        `len(pages) · page_size` leading tokens), and — when the next
        cached page agrees on a further partial run — a `(page_id,
        n_extra_tokens)` copy-on-write candidate, `0 < n_extra <
        page_size`. The caller takes its own references (`incref`) on
        whatever it uses; this method only reads and LRU-stamps the
        matched path."""
        ps = self.page_size
        node, i, pages = self.root, 0, []
        while True:
            child = node.children.get(tokens[i]) if i < len(tokens) else None
            if child is None:
                return pages, None
            m = _common_prefix(child.tokens, tokens[i:])
            full = m // ps
            self._touch(child)
            if m == len(child.tokens) and i + m < len(tokens):
                pages += child.pages
                node, i = child, i + m
                continue
            # divergence (or token exhaustion) inside this edge
            pages += child.pages[:full]
            extra = m - full * ps
            cow = (child.pages[full], extra) if extra else None
            return pages, cow

    # ------------------------------------------------------------------
    # insert
    # ------------------------------------------------------------------

    def insert(self, tokens: list[int], pages: list[int]) -> int:
        """Offer a finished stream's page-aligned prefix to the tree.

        `pages` must cover `tokens` exactly (`len(tokens) == len(pages) ·
        page_size`) and the caller's reference on every page is consumed:
        pages the tree adopts keep it (ownership transfer — no refcount
        traffic), pages already cached under the same tokens (or dropped
        for budget/misalignment reasons) are deref'd through
        `PagedKVCache.deref`, scrubbing any that hit refcount 0. Returns
        the number of pages adopted."""
        ps = self.page_size
        if len(tokens) != len(pages) * ps:
            raise ValueError(
                f"insert needs page-aligned tokens: {len(tokens)} tokens "
                f"vs {len(pages)} pages of {ps}")
        node, i, j = self.root, 0, 0
        adopted = 0
        while j < len(pages):
            child = node.children.get(tokens[i])
            if child is None:
                new = pages[j:]
                node_len = len(node.tokens)
                fit = self._make_room(len(new))
                # _make_room may evict *this very path* (the walk just
                # deref'd our duplicate refs, so its pages sit at
                # refcount 1): if the attach point was trimmed or
                # detached, a leaf hung off it would be unreachable —
                # give the pages back instead
                if not (self._attached(node)
                        and len(node.tokens) == node_len):
                    self.kv.deref(new)
                    return adopted
                if fit < len(new):
                    self.kv.deref(new[fit:])
                if fit:
                    leaf = RadixNode(tokens[i:i + fit * ps], new[:fit], node)
                    node.children[tokens[i]] = leaf
                    self._touch(leaf)
                    self.n_pages += fit
                    self.inserted_pages += fit
                    adopted += fit
                return adopted
            m = _common_prefix(child.tokens, tokens[i:])
            full = m // ps
            self._touch(child)
            if full == 0:
                # diverges inside the edge's first page: nothing below
                # this child is representable page-aligned
                self.kv.deref(pages[j:])
                return adopted
            # the overlapping run duplicates cached pages — drop ours
            # (usually the very pages we were admitted with, whose tree
            # refs are already held; deref also covers an independent
            # recompute of the same prefix)
            self.kv.deref(pages[j:j + full])
            if m < len(child.tokens):
                if m > full * ps:
                    # divergence mid-page past the aligned overlap: the
                    # remainder shares its first token with the split-off
                    # edge, so it cannot become a sibling — drop it
                    self._split(child, full)
                    self.kv.deref(pages[j + full:])
                    return adopted
                self._split(child, full)
                child = child.parent     # the new upper half
            node, i, j = child, i + full * ps, j + full
        return adopted

    def _attached(self, node: RadixNode) -> bool:
        """Is `node` still reachable from the root? Eviction removes
        emptied leaves, so a node held across a `_make_room` call may
        have left the tree."""
        while node.parent is not None:
            node = node.parent
        return node is self.root

    def _split(self, child: RadixNode, full: int):
        """Split `child` at `full` pages: a new upper node keeps the
        first `full` pages, `child` keeps the remainder below it."""
        ps = self.page_size
        upper = RadixNode(child.tokens[:full * ps], child.pages[:full],
                          child.parent)
        upper.last_access = child.last_access
        child.parent.children[child.tokens[0]] = upper
        child.tokens = child.tokens[full * ps:]
        child.pages = child.pages[full:]
        child.parent = upper
        upper.children[child.tokens[0]] = child

    def _make_room(self, n: int) -> int:
        """Pages of budget available for an insert of `n`, evicting LRU
        entries if needed; returns how many of the `n` fit."""
        if self.max_pages is None:
            return n
        over = self.n_pages + n - self.max_pages
        if over > 0:
            self.evict(over)
        return max(0, min(n, self.max_pages - self.n_pages))

    # ------------------------------------------------------------------
    # evict
    # ------------------------------------------------------------------

    def _leaves(self) -> list[RadixNode]:
        out = []

        def walk(node: RadixNode):
            for c in node.children.values():
                if c.children:
                    walk(c)
                else:
                    out.append(c)
        walk(self.root)
        return out

    def evict(self, n: int) -> int:
        """Free up to `n` tree-held pages, least-recently-used leaves
        first. A leaf is trimmed from the *tail* of its page run (later
        positions depend on earlier ones, never the reverse) and only
        pages whose sole holder is the tree are dropped — pages a live
        sequence still references are pinned and skipped. Returns the
        number of pages actually freed (deref'd at refcount 1, so they
        were scrubbed and returned to the allocator)."""
        freed = 0
        alloc = self.kv.allocator
        while freed < n:
            victims = sorted((leaf for leaf in self._leaves()),
                             key=lambda node: node.last_access)
            progressed = False
            for leaf in victims:
                # trim the longest evictable tail run of this leaf
                k = 0
                while k < len(leaf.pages) - 0 and freed + k < n \
                        and alloc.refcount(leaf.pages[-(k + 1)]) == 1:
                    k += 1
                if k == 0:
                    continue
                drop = leaf.pages[len(leaf.pages) - k:]
                ps = self.page_size
                del leaf.pages[len(leaf.pages) - k:]
                del leaf.tokens[len(leaf.tokens) - k * ps:]
                if not leaf.pages:
                    del leaf.parent.children[
                        next(t for t, c in leaf.parent.children.items()
                             if c is leaf)]
                self.kv.deref(drop)
                self.n_pages -= k
                self.evicted_pages += k
                freed += k
                progressed = True
                break           # re-rank: the trim may expose a parent
            if not progressed:
                break
        return freed

    def clear(self) -> int:
        """Drop every cached page (deref'd, scrubbing the exclusively
        held ones); returns how many the tree let go."""
        dropped = 0

        def walk(node: RadixNode):
            nonlocal dropped
            for c in list(node.children.values()):
                walk(c)
            if node is not self.root:
                self.kv.deref(node.pages)
                dropped += len(node.pages)
        walk(self.root)
        self.root = RadixNode([], [], None)
        self.n_pages = 0
        return dropped

    def held_pages(self) -> set[int]:
        """Every page id the tree currently references (accounting)."""
        out: set[int] = set()

        def walk(node: RadixNode):
            out.update(node.pages)
            for c in node.children.values():
                walk(c)
        walk(self.root)
        return out
