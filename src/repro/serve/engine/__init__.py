"""Paged-KV continuous-batching serving engine.

Unifies the three execution paths — bf16, fake-quant (PTQ hooks), and
packed-int4 integer serving — behind one `ServableModel` adapter, a paged
KV cache (`pages`: allocator + block tables), and a chunked-prefill
continuous-batching scheduler (`scheduler`). The data path is
block-table-native: the pool and block tables flow into each backend's
`forward_chunk`, which writes new KV rows into their pages and attends by
walking the table in `kernels.ops.paged_attention` — no gathered slab.
See each module's docstring for the design.
"""
from .adapter import (DenseModelAdapter, IntegerModelAdapter, ServableModel,
                      as_servable)
from .pages import PageAllocator, PagedKVCache, pages_for
from .scheduler import EngineRequest, SamplingParams, ServeEngine

__all__ = [
    "ServableModel", "DenseModelAdapter", "IntegerModelAdapter",
    "as_servable", "PageAllocator", "PagedKVCache", "pages_for",
    "EngineRequest", "SamplingParams", "ServeEngine",
]
