"""Paged continuous-batching serving engine — one scheduler, any family.

Unifies the three execution paths — bf16, fake-quant (PTQ hooks), and
packed-int4 integer serving — behind one `ServableModel` adapter, a
two-kind paged state (`pages`: KV page pools with block tables, plus
fixed-size register slot pools for SSM-style carried state), and a
chunked-prefill continuous-batching scheduler (`scheduler`). Each adapter
derives a `StateSpec` from its config, so dense/MoE (pure kv), pure SSM
(pure register), and hybrid (both) configs all run through the same
scheduler with no architecture branches. The kv data path is
block-table-native: the pools and block tables flow into each backend's
`forward_chunk`, which writes new KV rows into their pages and attends by
walking the table in `kernels.ops.paged_attention` — no gathered slab.
See each module's docstring for the design.
"""
from .adapter import (DenseModelAdapter, IntegerModelAdapter, ServableModel,
                      StateSpec, as_servable, derive_state_spec)
from .pages import (PageAllocator, PagedKVCache, RegisterAllocator,
                    pages_for)
from .scheduler import EngineRequest, SamplingParams, ServeEngine

__all__ = [
    "ServableModel", "StateSpec", "derive_state_spec", "DenseModelAdapter",
    "IntegerModelAdapter", "as_servable", "PageAllocator",
    "RegisterAllocator", "PagedKVCache", "pages_for", "EngineRequest",
    "SamplingParams", "ServeEngine",
]
