"""Paged continuous-batching serving engine — one scheduler, any family.

Unifies the three execution paths — bf16, fake-quant (PTQ hooks), and
packed-int4 integer serving — behind one `ServableModel` adapter, a
two-kind paged state (`pages`: KV page pools with block tables, plus
fixed-size register slot pools for SSM-style carried state), and a
chunked-prefill continuous-batching scheduler (`scheduler`). Each adapter
derives a `StateSpec` from its config, so dense/MoE (pure kv), pure SSM
(pure register), and hybrid (both) configs all run through the same
scheduler with no architecture branches. The kv data path is
block-table-native: the pools and block tables flow into each backend's
`forward_chunk`, which writes new KV rows into their pages and attends by
walking the table in `kernels.ops.paged_attention` — no gathered slab.

Admission comes in two policies. `"reserve"` (the default-off safety
baseline) commits worst-case `pages_for(prompt + max_new)` pages up
front, so a running sequence can never exhaust the pool — at the cost of
capping utilization under bursty traffic with pages nobody has written.
`"optimistic"` (the default) admits when the *prompt's* pages plus a
small headroom watermark fit, and recovers from mid-decode exhaustion by
preempting a victim: its pages are scrubbed and released through the
normal path, and the request replays later by re-prefilling its
host-known `prompt + generated` stream. Replay reproduces the identical
continuation — greedy decoding is deterministic, and sampling keys
derive from `(rid, position)`, never from a global step key — and a
request preempted past its bound fails terminally instead of
livelocking. Requests can also be cancelled (`ServeEngine.cancel`) or
expire against a deadline, and `faults.FaultPlan` injects deterministic
exhaustion/dispatch/lifecycle chaos for the robustness tests.

Prefix sharing (`prefix_cache=True`, kv-only specs) layers a radix tree
(`radix.RadixCache`) over the page pool under a refcount/copy-on-write
contract that `pages.PageAllocator` enforces: every page tracks its
holders (`alloc` → 1, `incref` adds, `free` decrements and recycles
only at zero), a page is written only by an exclusive owner — a
sequence extending a shared page first copies it via the fused
`PagedKVCache.cow_copy` dispatch — and scrub-on-release zeroes exactly
the pages that dropped to refcount 0 plus the released register slot,
in one fused dispatch per release. Finished requests donate their
page-aligned prefix to the tree (LRU budget; eviction under page
pressure runs before any preemption), admission starts `n_cached` at
the matched length so prefill begins at the divergence offset, and a
preempted victim's shared pages are unpinned, never scrubbed. Register
slots stay excluded from sharing: SSM state is position-dependent.
Tokens can stream per request via `submit(req, on_token=...)`,
delivered at step boundaries.

**Tiered residency** (`swap_host_mb`, kv-only specs) adds a host memory
tier under the device pool: each block-table entry is device-resident
(an `int` page id — the only residency kernels ever see), host-resident
(a `pages.HostPageRef` naming a slot of the `pages.HostSwapPool` numpy
mirror), or in-flight (inside a swap transfer window, asserted
untouchable by scrub/COW). Under page pressure `_handle_exhaustion`
applies the swap-vs-replay cost rule per victim: swap out when the
round-trip bytes (`2 · pages · page_bytes` — 4-8x smaller for the
quantized int4/int8 page formats) undercut the replay's re-prefill
tokens at the configured break-even rate, within the host budget;
otherwise preempt for recompute. Only *exclusively-held* pages move —
radix-shared pages keep the victim's reference and stay device-resident,
so a shared page swaps at most once and a COW source is never
host-resident. A swapped victim re-admits by swapping in (block-table
row patched in place, zero recomputed tokens, bit-identical
continuation); swap I/O failures (injectable: `faults.SwapFault`) retry
with exponential backoff, then degrade to recompute-by-replay, then —
past the preemption bound — terminal `failed`. `ServeEngine.drain()`
closes the loop: admission stops, in-flight work (including swapped
residents) finishes, and every tier must come back empty. See each
module's docstring for the design.
"""
from .adapter import (DenseModelAdapter, IntegerModelAdapter, ServableModel,
                      StateSpec, as_servable, derive_state_spec)
from .faults import DispatchFault, FaultPlan, SwapFault
from .pages import (HostPageRef, HostSwapPool, PageAllocator, PagedKVCache,
                    RegisterAllocator, pages_for)
from .radix import RadixCache, RadixNode
from .scheduler import (EngineRequest, EngineStalledError, SamplingParams,
                        ServeEngine)

__all__ = [
    "ServableModel", "StateSpec", "derive_state_spec", "DenseModelAdapter",
    "IntegerModelAdapter", "as_servable", "PageAllocator",
    "RegisterAllocator", "PagedKVCache", "pages_for", "EngineRequest",
    "EngineStalledError", "SamplingParams", "ServeEngine", "FaultPlan",
    "DispatchFault", "SwapFault", "RadixCache", "RadixNode",
    "HostPageRef", "HostSwapPool",
]
