"""Paged continuous-batching serving engine — one scheduler, any family.

Unifies the three execution paths — bf16, fake-quant (PTQ hooks), and
packed-int4 integer serving — behind one `ServableModel` adapter, a
two-kind paged state (`pages`: KV page pools with block tables, plus
fixed-size register slot pools for SSM-style carried state), and a
chunked-prefill continuous-batching scheduler (`scheduler`). Each adapter
derives a `StateSpec` from its config, so dense/MoE (pure kv), pure SSM
(pure register), and hybrid (both) configs all run through the same
scheduler with no architecture branches. The kv data path is
block-table-native: the pools and block tables flow into each backend's
`forward_chunk`, which writes new KV rows into their pages and attends by
walking the table in `kernels.ops.paged_attention` — no gathered slab.

Admission comes in two policies. `"reserve"` (the default-off safety
baseline) commits worst-case `pages_for(prompt + max_new)` pages up
front, so a running sequence can never exhaust the pool — at the cost of
capping utilization under bursty traffic with pages nobody has written.
`"optimistic"` (the default) admits when the *prompt's* pages plus a
small headroom watermark fit, and recovers from mid-decode exhaustion by
preempting a victim: its pages are scrubbed and released through the
normal path, and the request replays later by re-prefilling its
host-known `prompt + generated` stream. Replay reproduces the identical
continuation — greedy decoding is deterministic, and sampling keys
derive from `(rid, position)`, never from a global step key — and a
request preempted past its bound fails terminally instead of
livelocking. Requests can also be cancelled (`ServeEngine.cancel`) or
expire against a deadline, and `faults.FaultPlan` injects deterministic
exhaustion/dispatch/lifecycle chaos for the robustness tests. See each
module's docstring for the design.
"""
from .adapter import (DenseModelAdapter, IntegerModelAdapter, ServableModel,
                      StateSpec, as_servable, derive_state_spec)
from .faults import DispatchFault, FaultPlan
from .pages import (PageAllocator, PagedKVCache, RegisterAllocator,
                    pages_for)
from .scheduler import (EngineRequest, EngineStalledError, SamplingParams,
                        ServeEngine)

__all__ = [
    "ServableModel", "StateSpec", "derive_state_spec", "DenseModelAdapter",
    "IntegerModelAdapter", "as_servable", "PageAllocator",
    "RegisterAllocator", "PagedKVCache", "pages_for", "EngineRequest",
    "EngineStalledError", "SamplingParams", "ServeEngine", "FaultPlan",
    "DispatchFault",
]
