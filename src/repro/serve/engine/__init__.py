"""Paged-KV continuous-batching serving engine.

Unifies the three execution paths — bf16, fake-quant (PTQ hooks), and
packed-int4 integer serving — behind one `ServableModel` adapter, a paged
KV cache (`pages`), and a chunked-prefill continuous-batching scheduler
(`scheduler`). See each module's docstring for the design.
"""
from .adapter import (DenseModelAdapter, IntegerModelAdapter, ServableModel,
                      as_servable)
from .pages import PageAllocator, PagedKVCache, pages_for
from .scheduler import EngineRequest, SamplingParams, ServeEngine

__all__ = [
    "ServableModel", "DenseModelAdapter", "IntegerModelAdapter",
    "as_servable", "PageAllocator", "PagedKVCache", "pages_for",
    "EngineRequest", "SamplingParams", "ServeEngine",
]
