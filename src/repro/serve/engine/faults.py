"""Deterministic fault injection for the paged serving engine.

A `FaultPlan` is a seedable schedule of adversities the scheduler asks
about at well-defined points of each `step()`:

  * **allocator exhaustion** — `take_exhaustion(step)` makes the next
    page-growth attempt of that step raise `MemoryError` exactly as a
    genuinely empty free list would, driving the scheduler's preemption
    path without needing a pathological trace to fill the pool;
  * **dispatch faults** — `take_dispatch_fault(step)` injects a
    transient failure (`"fail"`: the fused device dispatch for that
    step's phase raises `DispatchFault` *before* launching, so engine
    state is untouched and the step simply makes no forward progress) or
    a delay (`"delay"`: the scheduler sleeps `dispatch_delay_s` before
    dispatching — wall-time histograms stretch, nothing else moves);
  * **lifecycle chaos** — `cancels_due(step, live)` / `expiries_due(
    step, live)` name requests the scheduler must cancel or force-expire
    at the top of that step, combining explicit `{step: (rid, ...)}`
    schedules with seeded random picks from the live set;
  * **swap I/O faults** — `take_swap_fault(step)` fails the step's
    first host<->device page transfer with `SwapFault` *before* any
    pool or ledger mutation, driving the scheduler's retry-with-backoff
    and fall-back-to-recompute degradation paths.

Determinism contract: every random decision is drawn from
`numpy.random.default_rng((seed, salt, step))` — a pure function of the
plan's seed and the step index, never of call order — so a chaos test
that replays the same plan against the same trace sees the same faults.
Injected exhaustions and dispatch faults fire at most once per step
(tracked in `_fired`): after the scheduler preempts a victim and
retries, the retry behaves like a real post-preemption allocator.

The injection points only ever (a) raise the same exceptions the real
system can raise, before any state mutation, or (b) call the engine's
public `cancel` / expiry paths — a plan can therefore never corrupt
state itself, which is what lets the chaos tests assert the engine's
invariants (no page/slot leaks, balanced books, bit-identical
survivors) under arbitrary plans.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class DispatchFault(RuntimeError):
    """Injected transient failure of a fused device dispatch (raised
    before the dispatch launches, so no engine state was touched)."""


class SwapFault(RuntimeError):
    """Injected failure of a host<->device page-swap transfer (raised
    before any pool or ledger mutation, so the scheduler can retry the
    swap with backoff or fall back to recompute-by-replay)."""


@dataclasses.dataclass
class FaultPlan:
    """Seedable, deterministic fault schedule for one engine run.

    Explicit schedules (`*_steps`, `*_at`) compose with random rates
    (`*_rate`, probability per engine step). Steps are the engine's
    internal step index, starting at 0 and never reset by
    `reset_metrics()`.
    """
    seed: int = 0
    # allocator exhaustion: force the step's first page-growth attempt
    # to raise MemoryError
    exhaust_steps: tuple[int, ...] = ()
    exhaust_rate: float = 0.0
    # dispatch faults: fail (no progress) or delay the fused dispatch
    dispatch_fail_steps: tuple[int, ...] = ()
    dispatch_fail_rate: float = 0.0
    dispatch_delay_steps: tuple[int, ...] = ()
    dispatch_delay_s: float = 0.0
    # lifecycle chaos: cancel / force-expire requests at step boundaries
    cancel_at: dict[int, tuple[int, ...]] = dataclasses.field(
        default_factory=dict)
    cancel_rate: float = 0.0
    expire_at: dict[int, tuple[int, ...]] = dataclasses.field(
        default_factory=dict)
    expire_rate: float = 0.0
    # swap I/O faults: fail the step's first host<->device page transfer
    swap_fail_steps: tuple[int, ...] = ()
    swap_fail_rate: float = 0.0

    def __post_init__(self):
        for name in ("exhaust_rate", "dispatch_fail_rate", "cancel_rate",
                     "expire_rate", "swap_fail_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.dispatch_delay_s < 0:
            raise ValueError(f"dispatch_delay_s must be >= 0, "
                             f"got {self.dispatch_delay_s}")
        for name in ("exhaust_steps", "dispatch_fail_steps",
                     "dispatch_delay_steps", "swap_fail_steps"):
            bad = [s for s in getattr(self, name) if s < 0]
            if bad:
                raise ValueError(
                    f"{name} has negative step index(es) {bad}")
        for name in ("cancel_at", "expire_at"):
            bad = [s for s in getattr(self, name) if s < 0]
            if bad:
                raise ValueError(
                    f"{name} has negative step index(es) {bad}")
        # at-most-once-per-step latches for the raising injections
        self._fired: set[tuple[str, int]] = set()

    # -- deterministic randomness ---------------------------------------

    def _rng(self, salt: int, step: int) -> np.random.Generator:
        """Pure function of (seed, salt, step) — call order never shifts
        the stream, so identical plans replay identical faults."""
        return np.random.default_rng((self.seed, salt, step))

    def _once(self, kind: str, step: int) -> bool:
        if (kind, step) in self._fired:
            return False
        self._fired.add((kind, step))
        return True

    # -- queries the scheduler makes ------------------------------------

    def take_exhaustion(self, step: int) -> bool:
        """True exactly once for a step whose growth should fail."""
        due = step in self.exhaust_steps or (
            self.exhaust_rate > 0
            and self._rng(1, step).random() < self.exhaust_rate)
        return due and self._once("exhaust", step)

    def take_dispatch_fault(self, step: int) -> str | None:
        """"fail", "delay", or None — at most one injection per step
        (an explicit fail schedule wins over an explicit delay)."""
        if step in self.dispatch_fail_steps or (
                self.dispatch_fail_rate > 0
                and self._rng(2, step).random() < self.dispatch_fail_rate):
            return "fail" if self._once("dispatch", step) else None
        if step in self.dispatch_delay_steps:
            return "delay" if self._once("dispatch", step) else None
        return None

    def take_swap_fault(self, step: int) -> bool:
        """True exactly once for a step whose first swap transfer should
        fail. The latch is shared across directions: whichever of
        swap-out / swap-in the scheduler attempts first that step takes
        the `SwapFault`; retries within the same step see a healthy
        tier, mirroring a transient host-I/O hiccup."""
        due = step in self.swap_fail_steps or (
            self.swap_fail_rate > 0
            and self._rng(5, step).random() < self.swap_fail_rate)
        return due and self._once("swap", step)

    def _lifecycle(self, step: int, live: list[int], at: dict, rate: float,
                   salt: int) -> list[int]:
        due = [rid for rid in at.get(step, ()) if rid in live]
        if rate > 0 and live:
            rng = self._rng(salt, step)
            if rng.random() < rate:
                pick = int(live[int(rng.integers(len(live)))])
                if pick not in due:
                    due.append(pick)
        return due

    def cancels_due(self, step: int, live: list[int]) -> list[int]:
        """Request ids (⊆ `live`) the scheduler must cancel this step."""
        return self._lifecycle(step, live, self.cancel_at,
                               self.cancel_rate, 3)

    def expiries_due(self, step: int, live: list[int]) -> list[int]:
        """Request ids (⊆ `live`) to force-expire this step, regardless
        of their wall-clock deadline (deterministic TTL testing)."""
        return self._lifecycle(step, live, self.expire_at,
                               self.expire_rate, 4)
