"""Unified model adapter: one `ServableModel` protocol for every path.

The engine serves three execution paths through one interface:

  * the bf16 `repro.models.transformer.Model` — any decode-capable token-LM
    family (dense, MoE, pure SSM, hybrid),
  * the fake-quant model from `pipeline.build_quantized_model` (the same
    `Model` class with PTQ hooks installed — quantization error included,
    weights stored dequantized),
  * the packed-int4 `repro.serve.quantized.QuantizedDenseLM` (true integer
    arithmetic, optional int8/int4 KV cache; dense archs).

Paged state is not KV-shaped by fiat. Each adapter derives a `StateSpec`
from its config declaring which state *kinds* the model carries:

  * `kv` — sequence-length-proportional state (attention caches), stored
    in page pools and addressed through per-sequence block tables;
  * `register` — fixed-size per-sequence state (a Mamba2 layer's conv tail
    and SSD state), stored in slot pools and addressed by one register
    slot per sequence, allocated at admission.

`init_state(n_pages, page_size, n_slots)` builds the partitioned
`{"kv": ..., "register": ...}` pytree the engine owns (the page/slot axis
is the batch axis), and `forward_chunk(params, tokens, state, index,
block_table, seq_lengths, register_index)` runs one [B, S] chunk against
it: kv rows are scattered straight into their pages and attention walks
the table through `kernels.ops.paged_attention`; register leaves are
gathered by slot at entry and scattered back once per call — no gathered
slab exists anywhere in the step. Dense models are pure kv (the spec has
no register part and `register_index` stays None), pure SSMs are pure
register (no block table), hybrids mix both kinds in one state pytree,
and MoE needs no extra state kind at all — its routed FFN rides inside
the forward. With `block_table` and `register_index` both None the same
entry serves the model's native dense contiguous cache (the test oracle
and the legacy scheduler).

Genuinely unservable configs fail fast in `derive_state_spec` with a
capability error: encoder-only families have no autoregressive decode,
and frontend (audio/vision) models are not token LMs.

The adapter wraps that pair, normalises cache dtype handling, maps the
partitioned engine state onto the model's native cache structure, and
jits the step end to end, so `scheduler.ServeEngine` never branches on
which backend — or which architecture family — runs underneath.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.serve.quantized import QuantizedDenseLM

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class StateSpec:
    """Which paged-state kinds a served model's cache carries.

    `kv`: grows with sequence length; block-table-indexed page pools.
    `register`: fixed size per sequence; slot-indexed register pools.
    `register_leaves` names the per-layer register leaves (accounting and
    tests; the engine itself only needs the booleans).
    """
    kv: bool
    register: bool
    register_leaves: tuple[str, ...] = ()

    @property
    def prefix_shareable(self) -> bool:
        """Whether the prefix-sharing radix cache may serve this spec:
        kv pages are position-addressable (row i depends only on tokens
        ≤ i), so a cached prefix page is valid for any sequence with the
        same leading tokens. Register (SSM conv/SSD) state is a running
        summary whose value at a position depends on how it was chunked
        — never shareable — so any spec carrying register state opts the
        whole model out rather than serving half its layers stale."""
        return self.kv and not self.register


def derive_state_spec(cfg) -> StateSpec:
    """Per-family state spec — the capability check for servability.

    Raises ValueError for configs the paged engine genuinely cannot
    serve: encoder-only families (no autoregressive decode step exists)
    and frontend models (the engine schedules token streams, not
    audio-frame/vision-patch prefixes).
    """
    if cfg.family == "encoder":
        raise ValueError(
            f"{cfg.name}: encoder-only family has no autoregressive decode "
            "step — there is nothing for the serving engine to schedule")
    if cfg.frontend is not None:
        raise ValueError(
            f"{cfg.name}: paged serving engine serves token LMs only "
            f"(frontend={cfg.frontend!r} supplies non-token inputs)")
    if cfg.family in ("dense", "vlm", "moe"):
        return StateSpec(kv=True, register=False)
    if cfg.family == "ssm":
        return StateSpec(kv=False, register=True,
                         register_leaves=("conv", "state"))
    if cfg.family == "hybrid":
        return StateSpec(kv=True, register=True,
                         register_leaves=("conv", "state"))
    raise ValueError(f"{cfg.name}: family {cfg.family!r} has no state spec")


@runtime_checkable
class ServableModel(Protocol):
    """What the paged engine needs from an execution path."""

    cfg: Any
    params: Params

    @property
    def state_spec(self) -> StateSpec:
        """Which state kinds `init_state` builds (drives admission)."""
        ...

    def init_state(self, n_pages: int, page_size: int,
                   n_slots: int) -> Params:
        """Partitioned `{"kv": ..., "register": ...}` paged state: kv
        leaves [n_layers, n_pages, page_size, ...], register leaves
        [n_layers, n_slots, ...]. Either part may be empty per the spec."""
        ...

    def init_cache(self, batch: int, max_len: int) -> Params:
        """The model's native dense contiguous cache (test oracle /
        legacy scheduler path)."""
        ...

    def forward_chunk(self, params: Params, tokens: jnp.ndarray,
                      cache: Params, index: jnp.ndarray,
                      block_table: jnp.ndarray | None = None,
                      seq_lengths: jnp.ndarray | None = None,
                      register_index: jnp.ndarray | None = None):
        """[B, S] tokens at fill position(s) `index` → ([B, S, V] logits,
        updated cache). In paged mode (`block_table` [B, P] and/or
        `register_index` [B] present) `cache` is the engine's partitioned
        state; `seq_lengths` [B] (true context lengths, 0 for padded
        rows) drive the paged kernel's ragged early-exit and mask padded
        prefill-chunk tails out of the SSM state recurrence. `params` is
        passed explicitly (usually `adapter.params`) so the engine's
        fused jits trace the weights as arguments, not as per-executable
        constants."""
        ...


class _AdapterBase:
    name: str
    # quality probes (serve.telemetry.quality) are instrumented on the
    # integer path; adapters that cannot run them advertise it so the
    # engine rejects a probed configuration at construction, not mid-run
    supports_quality_probes: bool = False

    def __init__(self, cfg, params: Params):
        # capability check: raises for encoder/frontend configs
        self.spec = derive_state_spec(cfg)
        self.cfg = cfg
        self.params = params

    @property
    def state_spec(self) -> StateSpec:
        return self.spec

    # -- partitioned engine state ↔ the model's native cache structure --

    def _merge(self, state: Params) -> Params:
        fam = self.cfg.family
        if fam == "ssm":
            return state["register"]
        if fam == "hybrid":
            return {"ssm": state["register"]["ssm"],
                    "shared": state["kv"]["shared"]}
        return state["kv"]

    def _split(self, caches: Params) -> Params:
        fam = self.cfg.family
        if fam == "ssm":
            return {"kv": {}, "register": caches}
        if fam == "hybrid":
            return {"kv": {"shared": caches["shared"]},
                    "register": {"ssm": caches["ssm"]}}
        return {"kv": caches, "register": {}}


class DenseModelAdapter(_AdapterBase):
    """bf16 or fake-quant `Model` of any servable family (the PTQ hooks
    ride along transparently)."""

    def __init__(self, model, params: Params, *, name: str = "bf16",
                 cache_dtype=jnp.float32):
        super().__init__(model.cfg, params)
        self.model = model
        self.name = name
        self.cache_dtype = cache_dtype
        self._forward = jax.jit(model.forward_chunk)

    def init_state(self, n_pages: int, page_size: int,
                   n_slots: int) -> Params:
        return self.model.init_paged_state(n_pages, page_size, n_slots,
                                           dtype=self.cache_dtype)

    def init_cache(self, batch: int, max_len: int) -> Params:
        return self.model.init_cache(batch, max_len, dtype=self.cache_dtype)

    def forward_chunk(self, params, tokens, cache, index, block_table=None,
                      seq_lengths=None, register_index=None, *,
                      probe=False):
        if probe:
            raise ValueError("quality probes are instrumented on the "
                             "integer path only (QuantizedDenseLM)")
        paged = block_table is not None or register_index is not None
        caches = self._merge(cache) if paged else cache
        logits, new = self._forward(params, tokens, caches,
                                    jnp.asarray(index, jnp.int32),
                                    block_table, seq_lengths, register_index)
        return logits, (self._split(new) if paged else new)


class IntegerModelAdapter(_AdapterBase):
    """Packed-int4 `QuantizedDenseLM` (params = packed weights). Dense
    archs only, so its state is pure kv."""

    supports_quality_probes = True

    def __init__(self, qlm: QuantizedDenseLM, packed_params: Params):
        super().__init__(qlm.cfg, packed_params)
        self.qlm = qlm
        self.name = f"int4_kv{qlm.kv_bits or 'bf16'}"

    def init_state(self, n_pages: int, page_size: int,
                   n_slots: int) -> Params:
        return {"kv": self.qlm.init_cache(n_pages, page_size),
                "register": {}}

    def init_cache(self, batch: int, max_len: int) -> Params:
        return self.qlm.init_cache(batch, max_len)

    def forward_chunk(self, params, tokens, cache, index, block_table=None,
                      seq_lengths=None, register_index=None, *,
                      probe=False):
        if register_index is not None:
            raise ValueError("integer path serves kv-only state")
        paged = block_table is not None
        caches = self._merge(cache) if paged else cache
        # QuantizedDenseLM jits internally (per kernels-enabled state)
        out = self.qlm.forward_chunk(params, tokens, caches, index,
                                     block_table, seq_lengths, probe=probe)
        if probe:
            logits, new, stats = out
            return logits, (self._split(new) if paged else new), stats
        logits, new = out
        return logits, (self._split(new) if paged else new)


def as_servable(model, params: Params, **kw) -> ServableModel:
    """Wrap any supported execution path in its engine adapter."""
    if isinstance(model, QuantizedDenseLM):
        return IntegerModelAdapter(model, params)
    return DenseModelAdapter(model, params, **kw)
