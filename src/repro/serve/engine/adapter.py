"""Unified model adapter: one `ServableModel` protocol for every path.

The engine serves three execution paths through one interface:

  * the bf16 `repro.models.transformer.Model`,
  * the fake-quant model from `pipeline.build_quantized_model` (the same
    `Model` class with PTQ hooks installed — quantization error included,
    weights stored dequantized),
  * the packed-int4 `repro.serve.quantized.QuantizedDenseLM` (true integer
    arithmetic, optional int8/int4 KV cache).

All three expose `init_cache` (which doubles as the page-pool constructor:
batch axis = page axis) and `forward_chunk(params, tokens, cache, index,
block_table)` — per-position logits for a [B, S] token chunk written at
fill position `index` (scalar, or [B] per-slot vector when S == 1). The
engine always passes its page pool as `cache` together with per-sequence
`block_table` rows, and the forward is block-table-native: new KV rows are
scattered straight into their pages and attention walks the table through
`kernels.ops.paged_attention` — no gathered slab exists anywhere in the
step. With `block_table=None` the same entry serves the dense contiguous
cache (the test oracle and the legacy scheduler). The adapter wraps that
pair, normalises cache dtype handling, and jits the step end to end, so
`scheduler.ServeEngine` never branches on which backend runs underneath.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.serve.quantized import QuantizedDenseLM

Params = dict[str, Any]


@runtime_checkable
class ServableModel(Protocol):
    """What the paged engine needs from an execution path."""

    cfg: Any
    params: Params

    def init_cache(self, batch: int, max_len: int) -> Params:
        """KV cache pytree with leading [n_layers, batch, max_len, ...]
        leaves. The engine calls this with (n_pages, page_size) to build
        the page pool."""
        ...

    def forward_chunk(self, params: Params, tokens: jnp.ndarray,
                      cache: Params, index: jnp.ndarray,
                      block_table: jnp.ndarray | None = None,
                      seq_lengths: jnp.ndarray | None = None):
        """[B, S] tokens at fill position(s) `index` → ([B, S, V] logits,
        updated cache). With `block_table` [B, P] the cache is the page
        pool and the forward is block-table-native; `seq_lengths` [B]
        (true context lengths, 0 for padded rows) drive the paged
        kernel's ragged early-exit. `params` is passed explicitly
        (usually `adapter.params`) so the engine's fused jits trace the
        weights as arguments, not as per-executable constants."""
        ...


class _AdapterBase:
    name: str

    def __init__(self, cfg, params: Params):
        if cfg.family not in ("dense", "vlm"):
            raise ValueError(
                f"paged serving engine requires position-indexed attention "
                f"caches (dense/vlm family), got {cfg.family!r}")
        if cfg.frontend is not None:
            raise ValueError("paged serving engine serves token LMs only")
        self.cfg = cfg
        self.params = params


class DenseModelAdapter(_AdapterBase):
    """bf16 or fake-quant `Model` (the hooks ride along transparently)."""

    def __init__(self, model, params: Params, *, name: str = "bf16",
                 cache_dtype=jnp.float32):
        super().__init__(model.cfg, params)
        self.model = model
        self.name = name
        self.cache_dtype = cache_dtype
        self._forward = jax.jit(model.forward_chunk)

    def init_cache(self, batch: int, max_len: int) -> Params:
        return self.model.init_cache(batch, max_len, dtype=self.cache_dtype)

    def forward_chunk(self, params, tokens, cache, index, block_table=None,
                      seq_lengths=None):
        return self._forward(params, tokens, cache,
                             jnp.asarray(index, jnp.int32), block_table,
                             seq_lengths)


class IntegerModelAdapter(_AdapterBase):
    """Packed-int4 `QuantizedDenseLM` (params = packed weights)."""

    def __init__(self, qlm: QuantizedDenseLM, packed_params: Params):
        super().__init__(qlm.cfg, packed_params)
        self.qlm = qlm
        self.name = f"int4_kv{qlm.kv_bits or 'bf16'}"

    def init_cache(self, batch: int, max_len: int) -> Params:
        return self.qlm.init_cache(batch, max_len)

    def forward_chunk(self, params, tokens, cache, index, block_table=None,
                      seq_lengths=None):
        # QuantizedDenseLM jits internally (per kernels-enabled state)
        return self.qlm.forward_chunk(params, tokens, cache, index,
                                      block_table, seq_lengths)


def as_servable(model, params: Params, **kw) -> ServableModel:
    """Wrap any supported execution path in its engine adapter."""
    if isinstance(model, QuantizedDenseLM):
        return IntegerModelAdapter(model, params)
    return DenseModelAdapter(model, params, **kw)
