"""Parameter / batch / cache / optimizer-state sharding inference.

Specs are derived from leaf *names* in the model param tree (the tree layout
is owned by `repro.models.transformer`, so the rules here are the single
source of truth for how every tensor class is laid out on the mesh).

ZeRO-3 ("fsdp") sharding of the non-model weight dim over ('pod','data') is
switched on per-arch for the ≥33B models; XLA then all-gathers weights
layer-by-layer inside the scan.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any

# archs whose weights must be ZeRO-3 sharded to fit v5e HBM
ZERO3_ARCHS = {"deepseek-coder-33b", "llama4-maverick-400b-a17b"}


def _axes(mesh: Mesh, *names):
    """Keep only axes present in this mesh; () → None."""
    out = tuple(n for n in names if n in mesh.axis_names)
    if not out:
        return None
    return out if len(out) > 1 else out[0]


def _dp(mesh):
    return _axes(mesh, "pod", "data")


def _fit(spec: P, shape, mesh: Mesh) -> P:
    """jit in_shardings require every sharded dim to divide evenly; replace
    non-dividing entries with replication (with_sharding_constraint-style
    padding is not available at the jit boundary)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= sizes[a]
        out.append(entry if dim % total == 0 else None)
    return P(*out)


def param_spec(path: str, ndim: int, mesh: Mesh, *, zero3: bool) -> P:
    """PartitionSpec for a parameter leaf. `path` is '/'-joined tree keys
    (a leading 'layers/' or 'shared_attn/' prefix may be present; stacked
    leaves have a leading L dim which is never sharded)."""
    name = path.split("/")[-1]
    stacked = path.startswith("layers/")
    lead = (None,) if stacked else ()
    mdl = _axes(mesh, "model")
    fsdp = _dp(mesh) if zero3 else None

    def spec(*dims):
        return P(*lead, *dims)

    if name in ("embed",):
        return P(mdl, fsdp)                     # [V, d] vocab-sharded
    if name in ("lm_head",):
        return P(fsdp, mdl)                     # [d, V]
    if name in ("frontend_proj",):
        return P(None, fsdp)
    if name in ("wq", "wk", "wv"):
        return spec(fsdp, mdl)                  # [d, h·dh] column-parallel
    if name == "wo":
        return spec(mdl, fsdp)                  # [h·dh, d] row-parallel
    if name in ("bq", "bk", "bv"):
        return spec(mdl)
    if name in ("w_gate", "w_up", "shared_gate", "shared_up"):
        if ndim - len(lead) == 3:               # MoE expert weights [E, d, f]
            return spec(mdl, fsdp, None)
        return spec(fsdp, mdl)
    if name in ("w_down", "shared_down"):
        if ndim - len(lead) == 3:               # [E, f, d]
            return spec(mdl, None, fsdp)
        return spec(mdl, fsdp)
    if name == "router":
        return spec(fsdp, mdl)                  # [d, E]
    if name == "in_proj":
        return spec(fsdp, mdl)                  # [d, 2di+2N+H]
    if name == "out_proj":
        return spec(mdl, fsdp)                  # [d_inner, d]
    if name in ("conv_w", "conv_b"):
        return spec(*([None] * (ndim - len(lead) - 1)), mdl)
    if name in ("A_log", "D", "dt_bias", "norm_scale"):
        return spec(mdl) if ndim - len(lead) == 1 else spec(None, mdl)
    if name in ("scale", "bias"):
        return spec(*([None] * (ndim - len(lead))))
    # fallback: replicate
    return P(*([None] * ndim))


def _tree_paths(tree: Params):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", k)) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def param_shardings(mesh: Mesh, params: Params, arch: str) -> Params:
    zero3 = arch in ZERO3_ARCHS
    paths, leaves, treedef = _tree_paths(params)
    specs = [_fit(param_spec(p, len(l.shape), mesh, zero3=zero3),
                  l.shape, mesh)
             for p, l in zip(paths, leaves)]
    return jax.tree_util.tree_unflatten(
        treedef, [NamedSharding(mesh, s) for s in specs])


def batch_shardings(mesh: Mesh, batch: Params) -> Params:
    dp = _dp(mesh)

    def spec(leaf):
        nd = len(leaf.shape)
        return NamedSharding(mesh, _fit(P(dp, *([None] * (nd - 1))),
                                        leaf.shape, mesh))

    return jax.tree.map(spec, batch)


SERVE_RULES = {
    # serving layout for ZeRO-3 archs: weights stay 2D-sharded (d_in over
    # ('pod','data'), d_out over 'model') and activations flow as psum'd
    # partials; batch is replicated so the data axes are free for weight
    # contraction dims; the KV cache spreads its sequence over every axis.
    "batch": None,
    "kv_cache_seq": ("pod", "data", "model"),
}


def serve_cache_shardings(mesh: Mesh, cache: Params) -> Params:
    """KV cache for the replicated-batch serving layout: sequence sharded
    over all mesh axes; SSM state/conv sharded on channels over 'model'."""
    all_axes = _axes(mesh, "pod", "data", "model")
    mdl = _axes(mesh, "model")

    def spec_for(path, shape):
        name = path.split("/")[-1]
        if name in ("k", "v"):
            return P(None, None, all_axes, None, None)
        if name == "conv":
            return P(None, None, None, mdl)
        if name == "state":
            return P(None, None, mdl, None, None)
        return P(*([None] * len(shape)))

    paths, leaves, treedef = _tree_paths(cache)
    return jax.tree_util.tree_unflatten(
        treedef,
        [NamedSharding(mesh, _fit(spec_for(p, l.shape), l.shape, mesh))
         for p, l in zip(paths, leaves)])


def cache_spec(path: str, shape, mesh: Mesh) -> P:
    """KV/SSM cache leaves (stacked [L, ...] or [G, ...] first dim).

    KV caches shard heads on 'model' when the head count divides the axis;
    otherwise they shard the *sequence* dim instead (flash-decoding style —
    replicating a 32k cache over 16 model shards would be a 16× HBM blowup,
    which is exactly what the GQA kv=8 archs hit on a 16-way TP mesh).
    """
    dp = _dp(mesh)
    mdl = _axes(mesh, "model")
    name = path.split("/")[-1]
    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if name in ("k", "v"):      # [L, B, S, KH, Dh]
        kh, s = shape[3], shape[2]
        if mdl is not None and kh % msize == 0:
            return P(None, dp, None, mdl, None)
        if mdl is not None and s % msize == 0:
            return P(None, dp, mdl, None, None)   # sequence-sharded cache
        return P(None, dp, None, None, None)
    if name == "conv":          # [L, B, W, C]
        return P(None, dp, None, mdl)
    if name == "state":         # [L, B, H, N, P]
        return P(None, dp, mdl, None, None)
    return P(*([None] * len(shape)))


def cache_shardings(mesh: Mesh, cache: Params) -> Params:
    paths, leaves, treedef = _tree_paths(cache)
    return jax.tree_util.tree_unflatten(
        treedef,
        [NamedSharding(mesh, _fit(cache_spec(p, l.shape, mesh),
                                  l.shape, mesh))
         for p, l in zip(paths, leaves)])


def opt_state_shardings(mesh: Mesh, opt_state: Params, params: Params,
                        arch: str) -> Params:
    pshard = param_shardings(mesh, params, arch)
    return {
        "step": NamedSharding(mesh, P()),
        "m": pshard,
        "v": pshard,
    }


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
