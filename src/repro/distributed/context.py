"""Mesh context + logical-axis sharding annotations (MaxText-style).

Models annotate activations with *logical* axis names; the rules below map
them to mesh axes. Outside a mesh context the annotations are no-ops, so the
same model code runs on a laptop and on a 512-chip mesh.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["mesh_context", "current_mesh", "logical_to_spec", "shard_act",
           "AXIS_RULES"]

_LOCAL = threading.local()

# logical axis → mesh axes (None = replicated). The "pod" axis extends data
# parallelism across pods; "fsdp_axes" is where ZeRO-3 weight shards live.
AXIS_RULES: dict[str, tuple[str, ...] | str | None] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": ("data",),        # sequence parallelism for long-context
    "embed": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "fsdp": ("pod", "data"),       # weight non-model dim for ZeRO-3 archs
    "kv_len": None,
    "kv_cache_seq": "model",       # sequence-sharded KV cache (flash-decode)
}


def mesh_axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get(name, 1)


@contextlib.contextmanager
def mesh_context(mesh: Mesh | None, rules: dict | None = None):
    """Enter a mesh (+ optional axis-rule overrides, e.g. the serving
    layout replicates 'batch' and spreads 'kv_cache_seq' over every axis)."""
    prev = getattr(_LOCAL, "mesh", None)
    prev_rules = getattr(_LOCAL, "rules", None)
    _LOCAL.mesh = mesh
    _LOCAL.rules = dict(AXIS_RULES, **(rules or {}))
    try:
        yield mesh
    finally:
        _LOCAL.mesh = prev
        _LOCAL.rules = prev_rules


def current_mesh() -> Mesh | None:
    return getattr(_LOCAL, "mesh", None)


def current_rules() -> dict:
    return getattr(_LOCAL, "rules", None) or AXIS_RULES


def _resolve(axis: str | None, mesh: Mesh) -> tuple[str, ...] | str | None:
    if axis is None:
        return None
    rule = current_rules().get(axis, None)
    if rule is None:
        return None
    names = set(mesh.axis_names)
    if isinstance(rule, str):
        return rule if rule in names else None
    picked = tuple(r for r in rule if r in names)
    return picked if picked else None


def logical_to_spec(logical: tuple[str | None, ...], mesh: Mesh) -> P:
    return P(*[_resolve(a, mesh) for a in logical])


def shard_act(x: jax.Array, logical: tuple[str | None, ...]):
    """with_sharding_constraint by logical axis names (no-op without mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(logical, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
