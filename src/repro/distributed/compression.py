"""Gradient compression: int8 error-feedback quantized reduction.

Used on the cross-pod data-parallel boundary, where ICI/DCN bandwidth is the
scarcest resource: gradients are quantized to int8 with a per-leaf scale
before the 'pod'-axis psum, and the quantization error is fed back into the
next step (error feedback keeps SGD convergence — Seide et al. 2014,
Karimireddy et al. 2019).

Two entry points:
  * `ef_compress_grads(grads, ef_state, axis)` — inside-jit variant. The
    grads arriving here are already averaged over ALL data axes by the
    backward pass; this op re-quantizes them so that what crosses the slow
    axis is the int8 payload: implemented as quantize → dequantize around a
    `lax.psum`-free identity (the sharding constraint keeps the payload int8
    across the 'pod' axis boundary), plus error feedback. On a single-jit
    mesh XLA has already reduced; the compression then models/enforces the
    low-precision payload and keeps the EF dynamics testable end-to-end.
  * `compressed_psum(x, axis_name)` — shard_map building block that performs
    the *actual* int8 psum for the pod-local-jit runtime mode (see
    repro.runtime): quantize → psum(int8-as-int32) → dequantize.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


def init_ef_state(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize_leaf(g: jnp.ndarray):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_compress_grads(grads: Params, ef_state: Params, *, axis: str = "pod"):
    """Quantize grads to int8 (+f32 scale) with error feedback.

    Returns (decompressed grads, new ef_state). The int8 tensor is what a
    cross-pod reduce ships; the residual (g − deq(q)) is carried to the next
    step so no gradient signal is lost in expectation.
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize_leaf(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    flat = jax.tree.map(one, grads, ef_state)
    out = jax.tree.map(lambda t: t[0], flat,
                       is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    return out, new_ef


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-payload psum for use inside shard_map (pod-local-jit mode).

    The local shard is quantized to int8; the psum carries int32 partial
    sums of the int8 payload plus one f32 scale per participant (the max
    scale is used for requantization — conservative but bias-free).
    """
    q, scale = _quantize_leaf(x.astype(jnp.float32))
    scale_max = jax.lax.pmax(scale, axis_name)
    # requantize against the shared scale so integer sums are consistent
    q_shared = jnp.clip(jnp.round(x.astype(jnp.float32) / scale_max),
                        -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q_shared, axis_name)
    return (total.astype(jnp.float32) * scale_max).astype(x.dtype)
