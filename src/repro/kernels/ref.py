"""Pure-jnp oracles for every Pallas kernel in this package.

The test suite sweeps shapes/dtypes and asserts the interpret-mode kernels
match these references; the benchmarks use them as the unfused baseline.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hadamard import block_hadamard_transform

from .paged_attention import paged_attention_reference as paged_attention_ref

__all__ = [
    "block_hadamard_ref",
    "hadamard_quant_ref",
    "int4_pack",
    "int4_unpack",
    "int4_matmul_ref",
    "quantize_act_int_ref",
    "paged_attention_ref",
]

# `paged_attention_ref` mirrors the flash-decoding Pallas kernel
# bit-for-bit: the identical split/combine reduction order, the same
# used-page skip, per-(head_block, q_block)-tile walks at the kernel
# instance's exact operand shapes (shared per-page helpers, same op
# order). The *independent* oracle for it is gather-to-slab +
# plain-softmax attention, asserted in the tests.


def block_hadamard_ref(x: jnp.ndarray, b: int) -> jnp.ndarray:
    """X · (I ⊗ H_b) over the last axis (normalized)."""
    return block_hadamard_transform(x, b)


def quantize_act_int_ref(x: jnp.ndarray, bits: int = 4):
    """Per-token (last-axis) asymmetric integer quantization.

    Returns (codes uint-range int8, scale f32 [..., 1], zero f32 [..., 1])
    with dequant  x̂ = scale · (codes + zero).
    """
    xf = x.astype(jnp.float32)
    mn = jnp.min(xf, axis=-1, keepdims=True)
    mx = jnp.max(xf, axis=-1, keepdims=True)
    s = jnp.maximum((mx - mn) / (2 ** bits - 1), jnp.finfo(jnp.float32).tiny)
    z = jnp.round(mn / s)
    codes = jnp.clip(jnp.round(xf / s) - z, 0, 2 ** bits - 1).astype(jnp.int8)
    return codes, s, z


def hadamard_quant_ref(x: jnp.ndarray, b: int, bits: int = 4):
    """Fused oracle: block-Hadamard rotate then per-token asym int quant."""
    return quantize_act_int_ref(block_hadamard_ref(x, b), bits)


def int4_pack(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack int4 codes (values in [-8, 7], stored int8) pairwise along axis 0:
    rows 2k (low nibble) and 2k+1 (high nibble) → uint8 [K/2, N]."""
    if codes.shape[0] % 2:
        raise ValueError("K must be even to pack nibbles")
    u = (codes.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    lo, hi = u[0::2], u[1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def int4_unpack(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of int4_pack → int8 codes in [-8, 7], shape [K, N]."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    k2, n = packed.shape
    out = jnp.stack([lo, hi], axis=1).reshape(2 * k2, n)
    return out


def int4_matmul_ref(act_codes: jnp.ndarray, act_scale: jnp.ndarray,
                    act_zero: jnp.ndarray, w_packed: jnp.ndarray,
                    w_scale: jnp.ndarray) -> jnp.ndarray:
    """Integer-arithmetic W4A4 GEMM oracle.

    act: per-token asym codes (uint range, int8 storage) with
         x̂ = s_a·(q_a + z_a); weights: packed symmetric int4 with
         ŵ = s_w·q_w (s_w per output channel, [N] or [1, N]).
    out = x̂ @ ŵ = s_a·s_w·(q_a @ q_w + z_a·Σ_k q_w).
    """
    w = int4_unpack(w_packed).astype(jnp.int32)            # [K, N]
    qa = act_codes.astype(jnp.int32)                        # [M, K]
    acc = qa @ w                                            # int32 [M, N]
    colsum = jnp.sum(w, axis=0, keepdims=True)              # [1, N]
    w_scale = w_scale.reshape(1, -1)
    return (act_scale * w_scale) * (acc.astype(jnp.float32)
                                    + act_zero * colsum.astype(jnp.float32))
