"""Pallas TPU kernel: block-table-native paged causal attention.

The serving engine used to gather every active sequence's KV pages into a
contiguous `[n_layers, B, P·page_size, ...]` slab, run dense attention on
it, and scatter the new rows back — one full HBM round trip of the whole
active context per decode step. This kernel deletes the slab: the grid is
`(batch, page_columns)` and each instance walks one sequence's block table
directly, DMA-ing one `[page_size, KH, Dh]` page at a time into VMEM via
scalar-prefetched page ids (`PrefetchScalarGridSpec` — the block-spec
index map reads `block_tables[b, p]` to pick which pool page to fetch).
Softmax runs online across the page walk (flash-style m/l/acc VMEM
accumulators, the page axis innermost so they stay resident), and the
output block is written once on the last page column.

Three KV page formats are served by the same walk:

  * float pages (bf16/f32) holding post-RoPE K — the bf16 and fake-quant
    engine backends;
  * int8/int4 code pages with per-(position, head-group) asymmetric
    scale/zero pages riding along — dequantized in VMEM, and (because the
    integer cache stores K pre-RoPE) rotated in-kernel with the absolute
    position of each page row.

Every arithmetic step lives in a small jnp helper shared with
`kernels.ref.paged_attention_ref`, which replays the identical page walk
on a gathered view — that is what makes the dispatch-vs-reference
comparison bit-for-bit in interpret mode, the same contract
`hadamard_quant`/`int4_matmul` already meet.

Padding is handled entirely by the causal mask: pad block-table entries
point at the scratch page, whose rows sit at slab positions greater than
every query position, so `kpos <= qpos` hides them exactly as it hides a
sequence's own not-yet-written rows.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_attention", "paged_attention_reference"]

MASK_VALUE = -1e30


# ---------------------------------------------------------------------------
# Shared arithmetic (kernel body AND the bit-for-bit jnp reference)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """Mirror of `models.layers.rope_frequencies` (kernels sit below the
    model layer, so the three lines are duplicated rather than imported).

    Computed host-side in numpy so the kernel operand and the reference's
    traced constant embed the *identical* literal — `pow` rounds a ulp
    differently between XLA's eager dispatch and constant folding, which
    would break the kernel-vs-reference bit-for-bit contract."""
    freqs = 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                             / np.float32(head_dim)))
    return jnp.asarray(freqs, jnp.float32)


def dequant_page(codes: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
                 *, bits: int, group: int) -> jnp.ndarray:
    """Asymmetric per-(row, head, group) dequant of one KV page.

    codes [T, KH, Dh] int8 (stored offset by 2^(bits-1)), scale/zero
    [T, KH, Dh/group] — the exact arithmetic of
    `QuantizedDenseLM._cache_read`.
    """
    off = 2 ** (bits - 1)
    shp = codes.shape
    cg = (codes.astype(jnp.float32) + off).reshape(
        *shp[:-1], shp[-1] // group, group)
    return (scale[..., None] * (cg + zero[..., None])).reshape(shp)


def rope_page(k: jnp.ndarray, kpos: jnp.ndarray,
              freqs: jnp.ndarray) -> jnp.ndarray:
    """Apply RoPE at absolute positions `kpos` [T] to one K page
    [T, KH, Dh] (f32) — `models.layers.apply_rope` arithmetic with the
    head axis broadcast."""
    ang = kpos[:, None].astype(jnp.float32) * freqs         # [T, Dh/2]
    cos, sin = jnp.cos(ang)[:, None, :], jnp.sin(ang)[:, None, :]
    x1, x2 = jnp.split(k.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


def page_update(m, l, acc, q, k, v, qpos, kpos, scale):
    """One online-softmax step over a single KV page.

    q [S, KH, G, Dh] f32, k/v [T, KH, Dh] f32, qpos [S], kpos [T];
    m/l [KH, G, S], acc [KH, G, S, Dh]. Fully-masked pages contribute
    exactly zero (exp underflows), so scratch-padded table columns are
    free no-ops.
    """
    logits = jnp.einsum("skgd,tkd->kgst", q, k) * scale
    valid = kpos[None, :] <= qpos[:, None]                   # [S, T]
    logits = jnp.where(valid[None, None], logits, MASK_VALUE)
    m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum("kgst,tkd->kgsd", p, v)
    return m_new, l_new, acc_new


def finalize(l, acc):
    """acc/l → [S, H, Dh] f32 (a single page walk degenerates to the plain
    softmax: one m/l pass ≡ exp(x−max)/Σ)."""
    kh, g, s, dh = acc.shape
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.einsum("kgsd->skgd", out).reshape(s, kh * g, dh)


# ---------------------------------------------------------------------------
# Kernel
# ---------------------------------------------------------------------------

def _kernel(bt_ref, *refs, s, kh, g, dh, t, scale, bits, group, theta):
    quant = bits is not None
    if quant:
        (q_ref, qpos_ref, k_ref, v_ref, ks_ref, kz_ref, vs_ref, vz_ref,
         fr_ref, o_ref, m_ref, l_ref, acc_ref) = refs
    else:
        q_ref, qpos_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32).reshape(s, kh, g, dh)
    qpos = qpos_ref[0]
    kpos = p * t + jax.lax.broadcasted_iota(jnp.int32, (1, t), 1)[0]
    if quant:
        k = dequant_page(k_ref[0], ks_ref[0], kz_ref[0],
                         bits=bits, group=group)
        v = dequant_page(v_ref[0], vs_ref[0], vz_ref[0],
                         bits=bits, group=group)
        if theta is not None:
            k = rope_page(k, kpos, fr_ref[...][0])
    else:
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)

    m, l, acc = page_update(m_ref[...], l_ref[...], acc_ref[...],
                            q, k, v, qpos, kpos, scale)
    m_ref[...] = m
    l_ref[...] = l
    acc_ref[...] = acc

    @pl.when(p == pl.num_programs(1) - 1)
    def _epilogue():
        o_ref[0] = finalize(l_ref[...], acc_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rope_theta", "kv_bits",
                                             "kv_group", "interpret"))
def paged_attention(q: jnp.ndarray, kv: dict, block_tables: jnp.ndarray,
                    q_positions: jnp.ndarray, *,
                    rope_theta: float | None = None,
                    kv_bits: int | None = None,
                    kv_group: int | None = None,
                    interpret: bool = True) -> jnp.ndarray:
    """Causal attention of `q` against one layer's KV page pool.

    q [B, S, H, Dh] (queries already rotated); kv {"k", "v"} pages
    [n_pages, T, KH, Dh] (+ "{k,v}_{scale,zero}" [n_pages, T, KH, Dh/g]
    when `kv_bits` is set); block_tables [B, P] int32 (pad = scratch);
    q_positions [B, S] int32 absolute positions. `rope_theta` rotates the
    dequantized K pages in-kernel (integer caches store K pre-RoPE).
    Returns [B, S, H, Dh] float32.
    """
    b, s, h, dh = q.shape
    t, kh = kv["k"].shape[1], kv["k"].shape[2]
    g = h // kh
    n_cols = block_tables.shape[1]
    quant = kv_bits is not None
    group = kv_group if quant else None
    if quant and dh % group:
        raise ValueError(f"head_dim {dh} not divisible by kv_group {group}")

    kern = functools.partial(
        _kernel, s=s, kh=kh, g=g, dh=dh, t=t, scale=1.0 / math.sqrt(dh),
        bits=kv_bits, group=group, theta=rope_theta if quant else None)

    def page_spec(last):
        return pl.BlockSpec((1, t, kh, last),
                            lambda bb, pp, bt: (bt[bb, pp], 0, 0, 0))

    in_specs = [
        pl.BlockSpec((1, s, h, dh), lambda bb, pp, bt: (bb, 0, 0, 0)),
        pl.BlockSpec((1, s), lambda bb, pp, bt: (bb, 0)),
        page_spec(dh),
        page_spec(dh),
    ]
    operands = [q, q_positions.astype(jnp.int32), kv["k"], kv["v"]]
    if quant:
        ng = dh // group
        in_specs += [page_spec(ng)] * 4
        operands += [kv["k_scale"], kv["k_zero"],
                     kv["v_scale"], kv["v_zero"]]
        in_specs.append(pl.BlockSpec((1, dh // 2),
                                     lambda bb, pp, bt: (0, 0)))
        operands.append(rope_frequencies(dh, rope_theta or 1.0)[None]
                        if rope_theta is not None
                        else jnp.zeros((1, dh // 2), jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, n_cols),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, s, h, dh),
                               lambda bb, pp, bt: (bb, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kh, g, s), jnp.float32),
            pltpu.VMEM((kh, g, s), jnp.float32),
            pltpu.VMEM((kh, g, s, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((b, s, h, dh), jnp.float32),
        grid_spec=grid_spec,
        interpret=interpret,
    )(block_tables.astype(jnp.int32), *operands)


# ---------------------------------------------------------------------------
# jnp reference (re-exported as `kernels.ref.paged_attention_ref`)
# ---------------------------------------------------------------------------

def paged_attention_reference(q: jnp.ndarray, kv: dict,
                              block_tables: jnp.ndarray,
                              q_positions: jnp.ndarray, *,
                              rope_theta: float | None = None,
                              kv_bits: int | None = None,
                              kv_group: int | None = None) -> jnp.ndarray:
    """Plain-XLA mirror of the kernel: the identical page walk (same
    helpers, same op order) as a `lax.scan` over table columns, vmapped
    over sequences — bit-for-bit against the interpret-mode kernel."""
    b, s, h, dh = q.shape
    t, kh = kv["k"].shape[1], kv["k"].shape[2]
    g = h // kh
    quant = kv_bits is not None
    scale = 1.0 / math.sqrt(dh)
    freqs = (rope_frequencies(dh, rope_theta)
             if quant and rope_theta is not None else None)

    def one_sequence(qb, qposb, btb):
        qb = qb.astype(jnp.float32).reshape(s, kh, g, dh)

        def step(carry, inp):
            p, page = inp
            kpos = p * t + jax.lax.broadcasted_iota(jnp.int32, (1, t), 1)[0]
            if quant:
                k = dequant_page(kv["k"][page], kv["k_scale"][page],
                                 kv["k_zero"][page],
                                 bits=kv_bits, group=kv_group)
                v = dequant_page(kv["v"][page], kv["v_scale"][page],
                                 kv["v_zero"][page],
                                 bits=kv_bits, group=kv_group)
                if freqs is not None:
                    k = rope_page(k, kpos, freqs)
            else:
                k = kv["k"][page].astype(jnp.float32)
                v = kv["v"][page].astype(jnp.float32)
            return page_update(*carry, qb, k, v, qposb, kpos, scale), None

        m0 = jnp.full((kh, g, s), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((kh, g, s), jnp.float32)
        a0 = jnp.zeros((kh, g, s, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (jnp.arange(block_tables.shape[1], dtype=jnp.int32), btb))
        return finalize(l, acc)

    return jax.vmap(one_sequence)(q, q_positions.astype(jnp.int32),
                                  block_tables)
