"""Pallas TPU kernel: flash-decoding block-table-native paged causal attention.

The first block-table-native kernel (PR 3) deleted the gather-to-slab round
trip, but its grid was `(batch, page_column)`: one grid instance serially
walked *every* table column of a sequence — scratch-padded columns included
— while all KV heads and every query row of a prefill chunk shared that
instance's VMEM accumulators. This rewrite scales the walk out across every
axis the hardware can parallelise:

    grid = (batch, kv_head_block, q_block, kv_split, page_column)

  * **KV-head and query-block axes** — each `(head_block, q_block)` tile
    owns its own `m/l/acc` VMEM scratch, so many-head configs and long
    prefill chunks spread over cores instead of serialising in one
    instance (the four outer axes are marked `parallel` for Mosaic; the
    page axis stays `arbitrary` since the online softmax is a carried
    reduction).
  * **Split-K page partitions** — the page axis is cut into `kv_splits`
    independent partial walks. Each split emits flash-decoding partials
    `(m, l, acc)`; a second LSE-combine kernel merges them with the
    standard log-sum-exp reweighting. Decode (S == 1) gets context-length
    parallelism this way: a 32-page context becomes `kv_splits` concurrent
    8-page walks plus one tiny combine.
  * **Ragged early-exit** — per-sequence used-page counts are
    scalar-prefetched alongside the block table, and every instance
    `pl.when`-skips columns past its sequence's last live page: neither
    the page DMA nor the softmax update runs for pad/scratch columns. The
    pages walked per decode step drop from `batch · n_cols` to
    `Σ_b ceil(len_b / page_size)` — a real work reduction for ragged
    batches (exact by construction: a fully-masked page leaves `m/l/acc`
    bitwise unchanged, so skipping it is a bit-for-bit no-op).
  * **Double-buffered page DMA** — the K/V code pages (the dominant byte
    stream) live in `ANY`/HBM and are copied into a two-slot VMEM buffer
    with `pltpu.make_async_copy`: the copy for column `p+1` is issued
    before the softmax update of column `p` consumes slot `p % 2`, so the
    DMA of the next page overlaps the current update in the Mosaic path
    (the tiny scale/zero pages ride the regular BlockSpec pipeline, which
    Mosaic double-buffers on its own).

Three KV page formats are served by the same walk:

  * float pages (bf16/f32) holding post-RoPE K — the bf16 and fake-quant
    engine backends;
  * int8/int4 code pages with per-(position, head-group) asymmetric
    scale/zero pages riding along — dequantized in VMEM, and (because the
    integer cache stores K pre-RoPE) rotated in-kernel with the absolute
    position of each page row.

Every arithmetic step lives in a small jnp helper shared with
`kernels.ref.paged_attention_ref`, which replays the *identical*
split/combine reduction order (same per-split column walk, same skip
select, same LSE combine) on a gathered view — that is what keeps the
dispatch-vs-reference comparison bit-for-bit in interpret mode for every
`(q_block, kv_splits, head_block)` configuration, the contract
`hadamard_quant`/`int4_matmul` already meet.

Padding is handled by the causal mask plus the early-exit: pad block-table
entries point at the scratch page and sit past the used-page count, so they
are skipped outright; rows of the last live page beyond the fill point are
hidden by `kpos <= qpos` exactly as a sequence's own not-yet-written rows.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_attention", "paged_attention_reference", "resolve_tiling",
           "used_page_counts", "rope_frequencies"]

MASK_VALUE = -1e30


# ---------------------------------------------------------------------------
# Shared arithmetic (kernel body AND the bit-for-bit jnp reference)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """Twin of `models.layers.rope_frequencies` — duplicated on purpose,
    and pinned to it by `tests/test_kernels.py::
    test_rope_frequency_literals_agree` (≤ 2 ulp) so it cannot drift.

    Computed host-side in numpy so the kernel operand and the reference's
    traced constant embed the *identical* literal — `pow` rounds a ulp
    differently between XLA's eager dispatch and constant folding, which
    would break the kernel-vs-reference bit-for-bit contract. That same
    rounding gap is why the model keeps its own traced-jnp twin: swapping
    it onto this literal shifts every rotation by the ulp difference."""
    freqs = 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                             / np.float32(head_dim)))
    return jnp.asarray(freqs, jnp.float32)


def dequant_page(codes: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
                 *, bits: int, group: int) -> jnp.ndarray:
    """Asymmetric per-(row, head, group) dequant of one KV page.

    codes [T, KH, Dh] int8 (stored offset by 2^(bits-1)), scale/zero
    [T, KH, Dh/group] — the exact arithmetic of
    `QuantizedDenseLM._cache_read`.
    """
    off = 2 ** (bits - 1)
    shp = codes.shape
    cg = (codes.astype(jnp.float32) + off).reshape(
        *shp[:-1], shp[-1] // group, group)
    return (scale[..., None] * (cg + zero[..., None])).reshape(shp)


def rope_page(k: jnp.ndarray, kpos: jnp.ndarray,
              freqs: jnp.ndarray) -> jnp.ndarray:
    """Apply RoPE at absolute positions `kpos` [T] to one K page
    [T, KH, Dh] (f32) — `models.layers.apply_rope` arithmetic with the
    head axis broadcast."""
    ang = kpos[:, None].astype(jnp.float32) * freqs         # [T, Dh/2]
    cos, sin = jnp.cos(ang)[:, None, :], jnp.sin(ang)[:, None, :]
    x1, x2 = jnp.split(k.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


def page_update(m, l, acc, q, k, v, qpos, kpos, scale):
    """One online-softmax step over a single KV page.

    q [S, KH, G, Dh] f32, k/v [T, KH, Dh] f32, qpos [S], kpos [T];
    m/l [KH, G, S], acc [KH, G, S, Dh]. KH/S may be the per-instance
    `head_block`/`q_block` tiles — every element's trajectory is
    independent, so tiling does not change a single bit. Fully-masked
    pages contribute exactly zero (exp underflows), so scratch-padded
    table columns are free no-ops.
    """
    logits = jnp.einsum("skgd,tkd->kgst", q, k) * scale
    valid = kpos[None, :] <= qpos[:, None]                   # [S, T]
    logits = jnp.where(valid[None, None], logits, MASK_VALUE)
    m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum("kgst,tkd->kgsd", p, v)
    return m_new, l_new, acc_new


def finalize(l, acc):
    """acc/l → [S, H, Dh] f32 (a single page walk degenerates to the plain
    softmax: one m/l pass ≡ exp(x−max)/Σ)."""
    kh, g, s, dh = acc.shape
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.einsum("kgsd->skgd", out).reshape(s, kh * g, dh)


def combine_partials(m, l, acc):
    """LSE-merge `kv_splits` flash-decoding partials into the output tile.

    m/l [KS, H, S], acc [KS, H, S, Dh] (H may be a `head_block · G` tile,
    S a `q_block` tile) → [S, H, Dh] f32. Splits that saw no live page
    carry m = -inf; their weight is forced to exactly zero so empty
    partitions are bit-for-bit no-ops (matching the in-walk skip).
    """
    mx = jnp.max(m, axis=0)                                  # [H, S]
    w = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - mx[None]))
    l_tot = jnp.sum(l * w, axis=0)                           # [H, S]
    acc_tot = jnp.sum(acc * w[..., None], axis=0)            # [H, S, Dh]
    out = acc_tot / jnp.maximum(l_tot[..., None], 1e-30)
    return jnp.einsum("hsd->shd", out)


# ---------------------------------------------------------------------------
# Tiling resolution (shared by the kernel dispatch and the reference)
# ---------------------------------------------------------------------------

def _largest_divisor(n: int, cap: int) -> int:
    for t in range(min(cap, n), 0, -1):
        if n % t == 0:
            return t
    return 1


# Decode split-K defaults: FIXED-WIDTH partitions (4 table columns per
# split, up to 8 splits). The resolver pins the split WIDTH and derives
# the split count from it — never `width = ceil(n_cols / kv_splits)`,
# which would move partition boundaries whenever the table widens. Fixed
# boundaries keep scratch-column widening bit-exact: widening only
# appends splits past every used-page count, whose partials carry
# m = -inf and thus exactly zero combine weight. Past
# `SPLIT_PAGE_COLS · MAX_KV_SPLITS` columns the cap forces wider splits,
# so boundaries do shift at table-width doublings there — a ulp-level
# effect covered by the engine's tolerance contract, not the bitwise one.
SPLIT_PAGE_COLS = 4
MAX_KV_SPLITS = 8


def resolve_tiling(s: int, kh: int, n_cols: int,
                   q_block: int | None = None,
                   kv_splits: int | None = None,
                   head_block: int | None = None
                   ) -> tuple[int, int, int, int]:
    """Shape-driven defaults for the grid axes — resolved identically on
    the kernel and reference paths so a `(q_block, kv_splits, head_block)`
    request means the same reduction order on both. Returns
    `(q_block, kv_splits, head_block, split_cols)` where `split_cols` is
    the page-column width of every split partition (the table is padded
    to `kv_splits · split_cols` scratch columns).

      * q_block: ≤ 8 query rows per instance (decode S=1 → 1, an 8-token
        prefill chunk → one block, a 32-token chunk → 4 blocks).
      * head_block: 1 KV head per instance — maximum head parallelism;
        the G query heads of the group ride along.
      * kv_splits: decode steps (S == 1) partition the page walk into
        fixed `SPLIT_PAGE_COLS`-wide splits, up to `MAX_KV_SPLITS`
        (context-length parallelism for the latency-critical path);
        prefill keeps one walk per (head, q-block) instance, which is
        already wide. An explicit `kv_splits` request gets equal-width
        `ceil(n_cols / kv_splits)` partitions instead.
    """
    if q_block is None:
        q_block = _largest_divisor(s, min(s, 8))
    if head_block is None:
        head_block = 1
    if kv_splits is None:
        if s == 1 and n_cols > SPLIT_PAGE_COLS:
            # width first, count second: boundaries at fixed multiples of
            # split_cols stay put when the table widens (see above)
            split_cols = max(SPLIT_PAGE_COLS, -(-n_cols // MAX_KV_SPLITS))
            kv_splits = -(-n_cols // split_cols)
        else:
            kv_splits, split_cols = 1, n_cols
    else:
        kv_splits = max(1, min(kv_splits, n_cols))
        split_cols = -(-n_cols // kv_splits)
    if s % q_block:
        raise ValueError(f"q_block {q_block} does not divide q_len {s}")
    if kh % head_block:
        raise ValueError(f"head_block {head_block} does not divide "
                         f"n_kv_heads {kh}")
    return q_block, kv_splits, head_block, split_cols


def used_page_counts(q_positions: jnp.ndarray,
                     seq_lengths: jnp.ndarray | None,
                     page_size: int, n_cols: int) -> jnp.ndarray:
    """[B] number of live table columns per sequence: ceil(len/page_size).

    `seq_lengths` comes from the scheduler (true per-sequence context
    lengths; 0 for padded batch rows → the whole walk is skipped). Without
    it the count is derived from the query positions — the causal mask
    hides every page past `max(qpos)+1` anyway, so trimming them is exact.
    """
    if seq_lengths is None:
        lens = jnp.max(q_positions, axis=1) + 1
    else:
        lens = seq_lengths
    return jnp.clip(-(-lens // page_size), 0, n_cols).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Kernels
# ---------------------------------------------------------------------------

def _kernel(bt_ref, used_ref, *refs, g, dh, t, scale, bits, group, theta,
            ncp, q_block, head_block, splits):
    quant = bits is not None
    if quant:
        (q_ref, qpos_ref, k_any, v_any, ks_ref, kz_ref, vs_ref, vz_ref,
         fr_ref, *rest) = refs
    else:
        q_ref, qpos_ref, k_any, v_any, *rest = refs
    if splits == 1:
        o_ref, m_ref, l_ref, acc_ref, k_buf, v_buf, sem = rest
    else:
        (mp_ref, lp_ref, ap_ref, m_ref, l_ref, acc_ref,
         k_buf, v_buf, sem) = rest

    b = pl.program_id(0)
    hb = pl.program_id(1)
    ks = pl.program_id(3)
    p = pl.program_id(4)
    col = ks * ncp + p
    used = used_ref[b]
    h0 = hb * head_block

    def page_dma(slot, c):
        """Async copies pool page `block_tables[b, c]`'s K/V head slice
        into VMEM slot `slot` (two copies, one DMA semaphore each)."""
        page = bt_ref[b, c]
        return (
            pltpu.make_async_copy(
                k_any.at[page, :, pl.ds(h0, head_block), :],
                k_buf.at[slot], sem.at[slot, 0]),
            pltpu.make_async_copy(
                v_any.at[page, :, pl.ds(h0, head_block), :],
                v_buf.at[slot], sem.at[slot, 1]),
        )

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # warm the pipe: fetch this split's first live column...
    @pl.when(jnp.logical_and(p == 0, col < used))
    def _first_fetch():
        for dma in page_dma(0, col):
            dma.start()

    # ...and issue the NEXT column's copy before the current update
    # consumes its slot — the DMA overlaps the softmax update below.
    @pl.when(jnp.logical_and(p + 1 < ncp, col + 1 < used))
    def _prefetch_next():
        for dma in page_dma((p + 1) % 2, col + 1):
            dma.start()

    @pl.when(col < used)
    def _update():
        for dma in page_dma(p % 2, col):
            dma.wait()
        q = q_ref[0].astype(jnp.float32).reshape(
            q_block, head_block, g, dh)
        qpos = qpos_ref[0]
        kpos = col * t + jax.lax.broadcasted_iota(jnp.int32, (1, t), 1)[0]
        if quant:
            k = dequant_page(k_buf[p % 2], ks_ref[0], kz_ref[0],
                             bits=bits, group=group)
            v = dequant_page(v_buf[p % 2], vs_ref[0], vz_ref[0],
                             bits=bits, group=group)
            if theta is not None:
                k = rope_page(k, kpos, fr_ref[...][0])
        else:
            k = k_buf[p % 2].astype(jnp.float32)
            v = v_buf[p % 2].astype(jnp.float32)
        m, l, acc = page_update(m_ref[...], l_ref[...], acc_ref[...],
                                q, k, v, qpos, kpos, scale)
        m_ref[...] = m
        l_ref[...] = l
        acc_ref[...] = acc

    @pl.when(p == ncp - 1)
    def _epilogue():
        hbg = head_block * g
        if splits == 1:
            o_ref[0] = finalize(l_ref[...], acc_ref[...]).astype(o_ref.dtype)
        else:
            mp_ref[0, 0] = m_ref[...].reshape(hbg, q_block)
            lp_ref[0, 0] = l_ref[...].reshape(hbg, q_block)
            ap_ref[0, 0] = acc_ref[...].reshape(hbg, q_block, dh)


def _combine_kernel(mp_ref, lp_ref, ap_ref, o_ref):
    o_ref[0] = combine_partials(mp_ref[0], lp_ref[0],
                                ap_ref[0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "rope_theta", "kv_bits", "kv_group", "q_block", "kv_splits",
    "head_block", "interpret"))
def paged_attention(q: jnp.ndarray, kv: dict, block_tables: jnp.ndarray,
                    q_positions: jnp.ndarray,
                    seq_lengths: jnp.ndarray | None = None, *,
                    rope_theta: float | None = None,
                    kv_bits: int | None = None,
                    kv_group: int | None = None,
                    q_block: int | None = None,
                    kv_splits: int | None = None,
                    head_block: int | None = None,
                    interpret: bool = True) -> jnp.ndarray:
    """Causal attention of `q` against one layer's KV page pool.

    q [B, S, H, Dh] (queries already rotated); kv {"k", "v"} pages
    [n_pages, T, KH, Dh] (+ "{k,v}_{scale,zero}" [n_pages, T, KH, Dh/g]
    when `kv_bits` is set); block_tables [B, P] int32 (pad = scratch);
    q_positions [B, S] int32 absolute positions; seq_lengths [B] optional
    true context lengths (pages past ceil(len/T) are skipped — 0 skips the
    row's whole walk). `rope_theta` rotates the dequantized K pages
    in-kernel (integer caches store K pre-RoPE). `q_block`/`kv_splits`/
    `head_block` pick the grid tiling (`resolve_tiling` defaults).
    Returns [B, S, H, Dh] float32.
    """
    b, s, h, dh = q.shape
    t, kh = kv["k"].shape[1], kv["k"].shape[2]
    g = h // kh
    n_cols = block_tables.shape[1]
    quant = kv_bits is not None
    group = kv_group if quant else None
    if quant and dh % group:
        raise ValueError(f"head_dim {dh} not divisible by kv_group {group}")
    q_block, kv_splits, head_block, ncp = resolve_tiling(
        s, kh, n_cols, q_block, kv_splits, head_block)
    n_hb, n_qb = kh // head_block, s // q_block
    hbg = head_block * g

    # partition the page axis into kv_splits × ncp-column walks; the grid
    # needs equal widths, so the table is padded with scratch columns
    # (past every used count — never walked)
    pad_cols = kv_splits * ncp - n_cols
    if pad_cols:
        block_tables = jnp.pad(block_tables, ((0, 0), (0, pad_cols)))
    used = used_page_counts(q_positions, seq_lengths, t, n_cols)

    kern = functools.partial(
        _kernel, g=g, dh=dh, t=t, scale=1.0 / math.sqrt(dh),
        bits=kv_bits, group=group, theta=rope_theta if quant else None,
        ncp=ncp, q_block=q_block, head_block=head_block, splits=kv_splits)

    def aux_page_spec(last):
        return pl.BlockSpec(
            (1, t, head_block, last),
            lambda bb, hh, qq, ss, pp, bt, u:
                (bt[bb, ss * ncp + pp], 0, hh, 0))

    in_specs = [
        pl.BlockSpec((1, q_block, hbg, dh),
                     lambda bb, hh, qq, ss, pp, bt, u: (bb, qq, hh, 0)),
        pl.BlockSpec((1, q_block),
                     lambda bb, hh, qq, ss, pp, bt, u: (bb, qq)),
        pl.BlockSpec(memory_space=pltpu.ANY),    # K pages: manual DMA
        pl.BlockSpec(memory_space=pltpu.ANY),    # V pages: manual DMA
    ]
    operands = [q, q_positions.astype(jnp.int32), kv["k"], kv["v"]]
    if quant:
        ng = dh // group
        in_specs += [aux_page_spec(ng)] * 4
        operands += [kv["k_scale"], kv["k_zero"],
                     kv["v_scale"], kv["v_zero"]]
        in_specs.append(pl.BlockSpec((1, dh // 2),
                                     lambda bb, hh, qq, ss, pp, bt, u:
                                     (0, 0)))
        operands.append(rope_frequencies(dh, rope_theta or 1.0)[None]
                        if rope_theta is not None
                        else jnp.zeros((1, dh // 2), jnp.float32))

    if kv_splits == 1:
        out_shape = jax.ShapeDtypeStruct((b, s, h, dh), jnp.float32)
        out_specs = pl.BlockSpec(
            (1, q_block, hbg, dh),
            lambda bb, hh, qq, ss, pp, bt, u: (bb, qq, hh, 0))
    else:
        out_shape = (
            jax.ShapeDtypeStruct((b, kv_splits, h, s), jnp.float32),
            jax.ShapeDtypeStruct((b, kv_splits, h, s), jnp.float32),
            jax.ShapeDtypeStruct((b, kv_splits, h, s, dh), jnp.float32),
        )
        ml_spec = pl.BlockSpec(
            (1, 1, hbg, q_block),
            lambda bb, hh, qq, ss, pp, bt, u: (bb, ss, hh, qq))
        out_specs = (
            ml_spec, ml_spec,
            pl.BlockSpec((1, 1, hbg, q_block, dh),
                         lambda bb, hh, qq, ss, pp, bt, u:
                         (bb, ss, hh, qq, 0)),
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_hb, n_qb, kv_splits, ncp),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((head_block, g, q_block), jnp.float32),
            pltpu.VMEM((head_block, g, q_block), jnp.float32),
            pltpu.VMEM((head_block, g, q_block, dh), jnp.float32),
            pltpu.VMEM((2, t, head_block, dh), kv["k"].dtype),
            pltpu.VMEM((2, t, head_block, dh), kv["v"].dtype),
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
    )
    result = pl.pallas_call(
        kern,
        out_shape=out_shape,
        grid_spec=grid_spec,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), used, *operands)
    if kv_splits == 1:
        return result

    m_p, l_p, acc_p = result
    ml_spec = pl.BlockSpec((1, kv_splits, hbg, q_block),
                           lambda bb, hh, qq: (bb, 0, hh, qq))
    return pl.pallas_call(
        _combine_kernel,
        out_shape=jax.ShapeDtypeStruct((b, s, h, dh), jnp.float32),
        grid=(b, n_hb, n_qb),
        in_specs=[
            ml_spec, ml_spec,
            pl.BlockSpec((1, kv_splits, hbg, q_block, dh),
                         lambda bb, hh, qq: (bb, 0, hh, qq, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hbg, dh),
                               lambda bb, hh, qq: (bb, qq, hh, 0)),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(m_p, l_p, acc_p)


# ---------------------------------------------------------------------------
# jnp reference (re-exported as `kernels.ref.paged_attention_ref`)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "rope_theta", "kv_bits", "kv_group", "q_block", "kv_splits",
    "head_block"))
def paged_attention_reference(q: jnp.ndarray, kv: dict,
                              block_tables: jnp.ndarray,
                              q_positions: jnp.ndarray,
                              seq_lengths: jnp.ndarray | None = None, *,
                              rope_theta: float | None = None,
                              kv_bits: int | None = None,
                              kv_group: int | None = None,
                              q_block: int | None = None,
                              kv_splits: int | None = None,
                              head_block: int | None = None) -> jnp.ndarray:
    """Plain-XLA mirror of the kernel: the identical split/combine
    reduction order — per-split column walks as `lax.scan`s with the same
    used-page skip, the same LSE combine, same helpers, same op order —
    replayed PER `(head_block, q_block)` TILE, vmapped over sequences.
    Tiling the element-independent head/query axes cannot change the math,
    but it does change the operand shapes XLA hands its dot kernels, and
    different gemm strategies round the d-contraction a ulp apart; walking
    each tile at exactly the kernel instance's shapes is what keeps the
    contract bit-for-bit for every `(q_block, kv_splits, head_block)`.
    jit'd like the kernel entry (an eagerly dispatched combine chain
    rounds a ulp away from the compiled one).
    """
    b, s, h, dh = q.shape
    t, kh = kv["k"].shape[1], kv["k"].shape[2]
    g = h // kh
    n_cols = block_tables.shape[1]
    quant = kv_bits is not None
    scale = 1.0 / math.sqrt(dh)
    q_block, kv_splits, head_block, ncp = resolve_tiling(
        s, kh, n_cols, q_block, kv_splits, head_block)
    pad_cols = kv_splits * ncp - n_cols
    if pad_cols:
        block_tables = jnp.pad(block_tables, ((0, 0), (0, pad_cols)))
    used = used_page_counts(q_positions, seq_lengths, t, n_cols)
    freqs = (rope_frequencies(dh, rope_theta)
             if quant and rope_theta is not None else None)

    def one_sequence(qb, qposb, btb, used_b):
        qb = qb.astype(jnp.float32).reshape(s, kh, g, dh)

        def one_tile(q_tile, qpos_tile, h0):
            # q_tile [q_block, head_block, g, dh] — one grid instance

            def step(carry, inp):
                col, page = inp
                kpos = col * t + jax.lax.broadcasted_iota(
                    jnp.int32, (1, t), 1)[0]
                hsl = slice(h0, h0 + head_block)
                if quant:
                    k = dequant_page(kv["k"][page][:, hsl],
                                     kv["k_scale"][page][:, hsl],
                                     kv["k_zero"][page][:, hsl],
                                     bits=kv_bits, group=kv_group)
                    v = dequant_page(kv["v"][page][:, hsl],
                                     kv["v_scale"][page][:, hsl],
                                     kv["v_zero"][page][:, hsl],
                                     bits=kv_bits, group=kv_group)
                    if freqs is not None:
                        k = rope_page(k, kpos, freqs)
                else:
                    k = kv["k"][page][:, hsl].astype(jnp.float32)
                    v = kv["v"][page][:, hsl].astype(jnp.float32)
                new = page_update(*carry, q_tile, k, v, qpos_tile, kpos,
                                  scale)
                # the kernel skips dead columns outright; selecting the
                # old carry replays that skip exactly
                keep = col < used_b
                carry = jax.tree.map(
                    lambda n, o: jnp.where(keep, n, o), new, carry)
                return carry, None

            def split_walk(split):
                cols = jnp.arange(split * ncp, (split + 1) * ncp,
                                  dtype=jnp.int32)
                init = (jnp.full((head_block, g, q_block), -jnp.inf,
                                 jnp.float32),
                        jnp.zeros((head_block, g, q_block), jnp.float32),
                        jnp.zeros((head_block, g, q_block, dh),
                                  jnp.float32))
                (m, l, acc), _ = jax.lax.scan(
                    step, init,
                    (cols, jax.lax.dynamic_slice_in_dim(btb, split * ncp,
                                                        ncp)))
                return m, l, acc

            hbg = head_block * g
            if kv_splits == 1:
                m, l, acc = split_walk(0)
                return finalize(l, acc)               # [q_block, hbg, dh]
            parts = [split_walk(i) for i in range(kv_splits)]
            m_p = jnp.stack([m.reshape(hbg, q_block) for m, _, _ in parts])
            l_p = jnp.stack([l.reshape(hbg, q_block) for _, l, _ in parts])
            acc_p = jnp.stack([a.reshape(hbg, q_block, dh)
                               for _, _, a in parts])
            return m_p, l_p, acc_p

        tiles = [[one_tile(qb[qi * q_block:(qi + 1) * q_block,
                              hb * head_block:(hb + 1) * head_block],
                           qposb[qi * q_block:(qi + 1) * q_block],
                           hb * head_block)
                  for hb in range(kh // head_block)]
                 for qi in range(s // q_block)]
        if kv_splits == 1:
            return jnp.concatenate(
                [jnp.concatenate(row, axis=1) for row in tiles], axis=0)
        # per-tile partial stacks [n_q_tiles, n_h_tiles, 3, KS, hbg, ...]
        return jax.tree.map(lambda *xs: jnp.stack(xs).reshape(
            s // q_block, kh // head_block, *xs[0].shape),
            *[t for row in tiles for t in row])

    out = jax.vmap(one_sequence)(q, q_positions.astype(jnp.int32),
                                 block_tables, used)
    if kv_splits == 1:
        return out

    # The combine runs OUTSIDE the vmapped walk, per (sequence, tile),
    # behind an optimization barrier: the kernel path's partials are
    # materialized pallas outputs (a hard fusion boundary), and without
    # the same boundary here XLA fuses the combine's multiply-adds into
    # the walk's producers as FMAs — a ulp apart from the kernel's
    # combine. Same shapes + same isolation ⇒ same lowering, bit for bit.
    m_p, l_p, acc_p = (jax.lax.optimization_barrier(x) for x in out)
    rows = []
    for bi in range(b):
        qrows = []
        for qi in range(s // q_block):
            tiles = [combine_partials(m_p[bi, qi, hb], l_p[bi, qi, hb],
                                      acc_p[bi, qi, hb])
                     for hb in range(kh // head_block)]
            qrows.append(jnp.concatenate(tiles, axis=1))
        rows.append(jnp.concatenate(qrows, axis=0))
    return jnp.stack(rows)
