"""jit'd public entry points for the kernels, with backend dispatch.

This module is the *only* kernel API the serving path uses: on TPU the
Pallas kernels compile to Mosaic; everywhere else (this CPU container,
debugging) they run in interpret mode or fall back to the jnp references.
`use_kernels(False)` forces the reference path (used by the dry-run, where
the XLA-level graph is what the roofline reads).

Entry points accept serving-path shapes directly: activations may carry
leading batch/seq dims ([..., K] codes with [..., 1] per-token asymmetric
scale/zero), and the packed-weight layout produced by `pack_int4_weights`
is the one `serve.quantized` stores per layer (vmapped under `lax.scan`).
The dispatch decision is made at trace time, so a `use_kernels(...)` scope
wrapped around a `jax.jit` trace bakes the chosen path into the compiled
function.
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from .block_hadamard import _column_tile, rotation_operand
from .block_hadamard import block_hadamard as _bh_kernel
from .hadamard_quant import hadamard_quant as _hq_kernel
from .int4_matmul import int4_matmul as _i4_kernel
from .paged_attention import paged_attention as _pa_kernel

__all__ = [
    "use_kernels",
    "kernels_enabled",
    "block_hadamard",
    "hadamard_quant",
    "quantize_act",
    "int4_matmul",
    "pack_int4_weights",
    "infer_int4_scales",
    "paged_attention",
    "dispatch_counts",
    "reset_dispatch_counts",
]

_STATE = {"enabled": True}

# -- dispatch telemetry -------------------------------------------------
# Per-(entry point, path) call tallies, kept as plain module state so the
# kernels layer stays free of any serve/telemetry import; the engine
# mirrors them into its MetricsRegistry at snapshot time as
# `kernels.dispatch.<entry>.<kernels|ref>` counters. These count
# *Python-level* calls: entry points are usually invoked inside a jit
# trace, so a tally ticks once per trace (or once per eager call), and
# the path tag records which backend that trace baked in — honest
# per-dispatch wall time lives in the scheduler's trace spans, where the
# caller can block_until_ready around a whole fused dispatch.
_DISPATCH: dict[tuple[str, str], int] = {}


def _record_dispatch(entry: str):
    key = (entry, "kernels" if _STATE["enabled"] else "ref")
    _DISPATCH[key] = _DISPATCH.get(key, 0) + 1


def dispatch_counts() -> dict[tuple[str, str], int]:
    """Snapshot of the per-entry-point call tallies (copy)."""
    return dict(_DISPATCH)


def reset_dispatch_counts():
    _DISPATCH.clear()


def kernels_enabled() -> bool:
    return _STATE["enabled"]


@contextlib.contextmanager
def use_kernels(enabled: bool):
    prev = _STATE["enabled"]
    _STATE["enabled"] = enabled
    try:
        yield
    finally:
        _STATE["enabled"] = prev


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def block_hadamard(x: jnp.ndarray, b: int) -> jnp.ndarray:
    """Online block rotation X·(I ⊗ H_b); Pallas on TPU, interpret elsewhere."""
    _record_dispatch("block_hadamard")
    if not kernels_enabled():
        return _ref.block_hadamard_ref(x, b)
    return _bh_kernel(x, b, interpret=not _on_tpu())


def _rotate_mm(x: jnp.ndarray, b: int) -> jnp.ndarray:
    """X·(I ⊗ H_b) as a dot against the block-diagonal rotation operand —
    the same arithmetic the TPU kernel performs (DESIGN.md §3), in plain
    XLA ops. Used by the reference serving path so `use_kernels(False)`
    is bit-compatible with the interpret-mode kernel (the butterfly FWHT
    in `ref.py` stays the *independent* oracle for the kernel tests)."""
    d = x.shape[-1]
    td = _column_tile(b, d)
    h = rotation_operand(b, td, dtype=jnp.float32)
    lead = x.shape[:-1]
    xs = x.astype(jnp.float32).reshape(-1, d // td, td)
    y = jax.lax.dot_general(xs, h,
                            dimension_numbers=(((2,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return y.reshape(*lead, d)


def hadamard_quant(x: jnp.ndarray, b: int, *, bits: int = 4):
    """Fused rotate+quantize → (codes, scale, zero); x may be [..., D]."""
    _record_dispatch("hadamard_quant")
    if not kernels_enabled():
        return _ref.quantize_act_int_ref(_rotate_mm(x, b), bits)
    return _hq_kernel(x, b, bits=bits, interpret=not _on_tpu())


def quantize_act(x: jnp.ndarray, bits: int = 4):
    """Per-token asymmetric activation quantization → (codes, scale, zero).

    Kernel path reuses the fused rotate+quantize kernel with block size 1
    (identity rotation), so the row min/max walk stays in VMEM; reference
    path is the jnp oracle.
    """
    _record_dispatch("quantize_act")
    if not kernels_enabled():
        return _ref.quantize_act_int_ref(x, bits)
    return _hq_kernel(x, 1, bits=bits, interpret=not _on_tpu())


def int4_matmul(act_codes, act_scale, act_zero, w_packed, w_scale,
                **kw) -> jnp.ndarray:
    """True-integer W4A4 GEMM; activations may carry leading dims.

    act_codes [..., K] int8 with per-token asymmetric act_scale/act_zero
    [..., 1]; w_packed [K/2, N] uint8 nibbles, w_scale [N] (or [1, N]) per
    output channel. Returns [..., N] float32.
    """
    _record_dispatch("int4_matmul")
    lead = act_codes.shape[:-1]
    k = act_codes.shape[-1]
    qa = act_codes.reshape(-1, k)
    sa = act_scale.reshape(-1, 1)
    za = act_zero.reshape(-1, 1)
    if not kernels_enabled():
        out = _ref.int4_matmul_ref(qa, sa, za, w_packed, w_scale)
    else:
        out = _i4_kernel(qa, sa, za, w_packed, w_scale,
                         interpret=not _on_tpu(), **kw)
    return out.reshape(*lead, out.shape[-1])


def paged_attention(q: jnp.ndarray, kv: dict, block_tables: jnp.ndarray,
                    q_positions: jnp.ndarray,
                    seq_lengths: jnp.ndarray | None = None, *,
                    rope_theta: float | None = None,
                    kv_bits: int | None = None,
                    kv_group: int | None = None,
                    q_block: int | None = None,
                    kv_splits: int | None = None,
                    head_block: int | None = None) -> jnp.ndarray:
    """Block-table-native causal attention over one layer's KV page pool.

    q [B, S, H, Dh] (already rotated), kv pages [n_pages, T, KH, Dh]
    (float post-RoPE K, or int8/int4 codes + scale/zero pages with
    `kv_bits`/`kv_group` set — dequant and the pre-RoPE K rotation happen
    inside the walk), block_tables [B, P] int32, q_positions [B, S],
    seq_lengths [B] optional true context lengths — the ragged early-exit
    skips every table column past ceil(len/page_size) (0 skips a padded
    row's walk entirely). `q_block`/`kv_splits`/`head_block` tile the
    flash-decoding grid (`resolve_tiling` defaults); both paths resolve
    them identically, so the split/combine reduction order matches.
    Pallas on TPU, interpret elsewhere, the bit-identical jnp page walk
    under `use_kernels(False)`. Returns [B, S, H, Dh] f32.
    """
    _record_dispatch("paged_attention")
    if not kernels_enabled():
        return _ref.paged_attention_ref(
            q, kv, block_tables, q_positions, seq_lengths,
            rope_theta=rope_theta, kv_bits=kv_bits, kv_group=kv_group,
            q_block=q_block, kv_splits=kv_splits, head_block=head_block)
    return _pa_kernel(q, kv, block_tables, q_positions, seq_lengths,
                      rope_theta=rope_theta, kv_bits=kv_bits,
                      kv_group=kv_group, q_block=q_block,
                      kv_splits=kv_splits, head_block=head_block,
                      interpret=not _on_tpu())


def infer_int4_scales(w: jnp.ndarray) -> jnp.ndarray:
    """Recover per-output-channel symmetric int4 scales from a [K, N] weight.

    PTQ hands the serving packer weights that are *already rounded* to a
    symmetric int4 grid k·s (k ∈ [-7, 7]), but the scale s itself is not
    stored in the PTQ result. `absmax/7` only recovers s when some channel
    code hits ±7 — GPTQ/Qronos error diffusion can leave a channel's max
    code below 7, in which case absmax/7 silently re-grids the channel and
    the integer path drifts from fake-quant. Searching s ∈ {absmax/m,
    m = 7..1} for the minimum round-trip error recovers the exact grid for
    every on-grid channel (m = max |code|) and degrades to absmax/7 for
    channels that were never on a grid.
    """
    wf = w.astype(jnp.float32)
    absmax = jnp.maximum(jnp.max(jnp.abs(wf), axis=0), 1e-12)      # [N]
    ms = jnp.arange(7, 0, -1, dtype=jnp.float32)                    # prefer 7
    cands = absmax[None, :] / ms[:, None]                           # [7, N]

    def roundtrip_err(s):
        q = jnp.clip(jnp.round(wf / s[None]), -7, 7) * s[None]
        return jnp.sum(jnp.square(q - wf), axis=0)

    errs = jax.vmap(roundtrip_err)(cands)                           # [7, N]
    best = jnp.argmin(errs, axis=0)                                 # first min
    return jnp.take_along_axis(cands, best[None], axis=0)[0]


def pack_int4_weights(w: jnp.ndarray, scale: jnp.ndarray | None = None):
    """Quantize a [K, N] float weight symmetrically to int4 and pack.

    The one shared packer for the serving path and the kernel benchmarks —
    vmap it over a leading layer axis to pack a whole `lax.scan` stack.
    `scale` is per output channel ([N] or [1, N], e.g. from
    `int_weight_scales_mse`); when None the grid is recovered from the
    weights via `infer_int4_scales`. Returns {"packed": uint8 [K/2, N],
    "scale": float32 [N]}.
    """
    wf = w.astype(jnp.float32)
    if scale is None:
        scale = infer_int4_scales(wf)
    scale = scale.reshape(-1).astype(jnp.float32)
    codes = jnp.clip(jnp.round(wf / scale[None]), -7, 7).astype(jnp.int8)
    return {"packed": _ref.int4_pack(codes), "scale": scale}
