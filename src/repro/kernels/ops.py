"""jit'd public entry points for the kernels, with backend dispatch.

On TPU the Pallas kernels compile to Mosaic; everywhere else (this CPU
container, debugging) they run in interpret mode or fall back to the jnp
references. `use_kernels(False)` forces the reference path (used by the
dry-run, where the XLA-level graph is what the roofline reads).
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from .block_hadamard import block_hadamard as _bh_kernel
from .hadamard_quant import hadamard_quant as _hq_kernel
from .int4_matmul import int4_matmul as _i4_kernel

__all__ = [
    "use_kernels",
    "kernels_enabled",
    "block_hadamard",
    "hadamard_quant",
    "int4_matmul",
    "pack_int4_weights",
]

_STATE = {"enabled": True}


def kernels_enabled() -> bool:
    return _STATE["enabled"]


@contextlib.contextmanager
def use_kernels(enabled: bool):
    prev = _STATE["enabled"]
    _STATE["enabled"] = enabled
    try:
        yield
    finally:
        _STATE["enabled"] = prev


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def block_hadamard(x: jnp.ndarray, b: int) -> jnp.ndarray:
    """Online block rotation X·(I ⊗ H_b); Pallas on TPU, interpret elsewhere."""
    if not kernels_enabled():
        return _ref.block_hadamard_ref(x, b)
    return _bh_kernel(x, b, interpret=not _on_tpu())


def hadamard_quant(x: jnp.ndarray, b: int, *, bits: int = 4):
    """Fused rotate+quantize → (codes, scale, zero)."""
    if not kernels_enabled():
        return _ref.hadamard_quant_ref(x, b, bits)
    return _hq_kernel(x, b, bits=bits, interpret=not _on_tpu())


def int4_matmul(act_codes, act_scale, act_zero, w_packed, w_scale,
                **kw) -> jnp.ndarray:
    """True-integer W4A4 GEMM."""
    if not kernels_enabled():
        return _ref.int4_matmul_ref(act_codes, act_scale, act_zero,
                                    w_packed, w_scale)
    return _i4_kernel(act_codes, act_scale, act_zero, w_packed, w_scale,
                      interpret=not _on_tpu(), **kw)


def pack_int4_weights(w: jnp.ndarray, scale: jnp.ndarray):
    """Quantize a [K, N] float weight symmetrically to int4 and pack.

    Returns (packed uint8 [K/2, N], scale [1, N]). `scale` is per output
    channel (e.g. from `int_weight_scales_mse`), already applied.
    """
    scale = scale.reshape(1, -1)
    codes = jnp.clip(jnp.round(w / scale), -7, 7).astype(jnp.int8)
    return _ref.int4_pack(codes), scale
