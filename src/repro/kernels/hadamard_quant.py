"""Pallas TPU kernel: fused block-Hadamard rotation + dynamic per-token
asymmetric integer quantization (the R̃₃ → Q_A path of Figure 7).

Fusing saves one full HBM round-trip of the rotated activation: unfused, the
rotation writes [M, D] bf16 to HBM and the quantizer reads it back; fused,
the rotated tile never leaves VMEM and only int codes + 2 floats per token
are written (a ~4× reduction in bytes moved for bf16 inputs at 4 bits).

Per-token quantization needs full-row min/max, so the grid tiles rows only
and each instance holds one [TM, D] strip (D ≤ 19200 f32 ≈ 75 KB/row — a
TM=64 strip is < 5 MiB of VMEM). The rotation applies H per column slab via
a dot against the block-diagonal operand, reusing `rotation_operand`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .block_hadamard import rotation_operand, _column_tile

__all__ = ["hadamard_quant"]


def _kernel(x_ref, h_ref, codes_ref, scale_ref, zero_ref, *, bits, n_slabs):
    x = x_ref[...].astype(jnp.float32)          # [TM, D]
    h = h_ref[...]                               # [TD, TD] block-diag operand
    tm, d = x.shape
    td = h.shape[0]
    # Rotate slab-by-slab (static unroll keeps everything MXU matmuls).
    xs = x.reshape(tm, n_slabs, td)
    y = jax.lax.dot_general(
        xs, h, dimension_numbers=(((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # [TM, n_slabs, TD]
    y = y.reshape(tm, d)
    mn = jnp.min(y, axis=-1, keepdims=True)
    mx = jnp.max(y, axis=-1, keepdims=True)
    s = jnp.maximum((mx - mn) / (2 ** bits - 1), jnp.finfo(jnp.float32).tiny)
    z = jnp.round(mn / s)
    codes = jnp.clip(jnp.round(y / s) - z, 0, 2 ** bits - 1)
    codes_ref[...] = codes.astype(jnp.int8)
    scale_ref[...] = s
    zero_ref[...] = z


@functools.partial(jax.jit, static_argnames=("b", "bits", "row_tile", "interpret"))
def hadamard_quant(x: jnp.ndarray, b: int, *, bits: int = 4,
                   row_tile: int = 64, interpret: bool = True):
    """Rotate by (I ⊗ H_b) and quantize per token.

    Returns (codes int8 [..., D] in [0, 2^bits−1], scale f32 [..., 1],
    zero f32 [..., 1]) with dequant x̂ = scale·(codes + zero).
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    if d % b:
        raise ValueError(f"feature dim {d} not divisible by block size {b}")
    m = int(np.prod(orig_shape[:-1])) if len(orig_shape) > 1 else 1
    x2 = x.reshape(m, d)

    td = _column_tile(b, d)
    n_slabs = d // td
    tm = min(row_tile, max(8, m))
    pad_m = (-m) % tm
    if pad_m:
        x2 = jnp.pad(x2, ((0, pad_m), (0, 0)), constant_values=1.0)
    mp = x2.shape[0]

    h_op = rotation_operand(b, td, dtype=jnp.float32)

    kern = functools.partial(_kernel, bits=bits, n_slabs=n_slabs)
    codes, scale, zero = pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((mp, d), jnp.int8),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        ),
        grid=(mp // tm,),
        in_specs=[
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
            pl.BlockSpec((td, td), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i: (i, 0)),
        ),
        interpret=interpret,
    )(x2, h_op)

    if pad_m:
        codes, scale, zero = codes[:m], scale[:m], zero[:m]
    lead = orig_shape[:-1]
    return (codes.reshape(*lead, d), scale.reshape(*lead, 1),
            zero.reshape(*lead, 1))
