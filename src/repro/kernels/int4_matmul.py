"""Pallas TPU kernel: W4A4 integer GEMM with in-kernel nibble unpack.

out = x̂ @ ŵ where x̂ = s_a·(q_a + z_a) (per-token asymmetric int4 codes from
`hadamard_quant`) and ŵ = s_w·q_w (symmetric int4, packed two rows per byte,
per-output-channel scale).

    out = s_a · s_w · (q_a @ q_w  +  z_a · colsum(q_w))

The integer product q_a @ q_w accumulates in int32 on the MXU (int8×int8
dot), the correction term uses precomputed int32 column sums, and the float
epilogue applies both scales — i.e. true integer arithmetic, not fake-quant.

Grid (M/TM, N/TN, K/TK) with a VMEM accumulator scratch; K is the innermost
(fastest) grid axis so the accumulator tile stays resident across the K walk.
Weights stay packed in HBM (half the bytes of int8) and are unpacked in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["int4_matmul"]


def _divisor_tile(dim: int, pref: int, *, multiple: int = 1) -> int:
    """Largest divisor of `dim` that is ≤ `pref` and a multiple of
    `multiple` — serving dims (head counts × head_dim, FFN widths) are not
    always multiples of the preferred MXU tile."""
    for t in range(min(pref, dim), multiple - 1, -1):
        if dim % t == 0 and t % multiple == 0:
            return t
    raise ValueError(f"no tile ≤ {pref} (multiple of {multiple}) "
                     f"divides {dim}")


def _tile_or_pad(dim: int, pref: int, *, multiple: int = 1) -> tuple[int, int]:
    """(tile, padded_dim) for an awkward dimension.

    Prefers an exact divisor tile (padded_dim == dim). A dimension whose
    only divisors ≤ `pref` are tiny — e.g. 2·prime projection widths,
    where the best "tile" is 1 or 2 — would either hard-fail or crawl, so
    it falls back to the preferred tile with zero padding: padded rows of
    the activation/weight operands contribute exactly zero to every real
    output element (0-codes × 0-weights, and zero weight columns add
    nothing to the colsum correction), and the pad is sliced off the
    result.
    """
    try:
        t = _divisor_tile(dim, pref, multiple=multiple)
        if t >= min(pref, 8, dim):
            return t, dim
    except ValueError:
        pass
    t = max(multiple, min(pref, -(-dim // multiple) * multiple))
    t -= t % multiple
    return t, dim + (-dim) % t


def _kernel(qa_ref, wp_ref, sa_ref, za_ref, sw_ref, colsum_ref, o_ref,
            acc_ref, *, n_k):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qa = qa_ref[...].astype(jnp.int32)            # [TM, TK]
    wp = wp_ref[...]                               # [TK/2, TN] packed uint8
    lo = (wp & 0xF).astype(jnp.int32)
    hi = ((wp >> 4) & 0xF).astype(jnp.int32)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    tk2, tn = wp.shape
    w = jnp.stack([lo, hi], axis=1).reshape(2 * tk2, tn)   # [TK, TN] int32
    acc_ref[...] += jax.lax.dot_general(
        qa, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k_idx == n_k - 1)
    def _epilogue():
        sa = sa_ref[...]                           # [TM, 1]
        za = za_ref[...]                           # [TM, 1]
        sw = sw_ref[...]                           # [1, TN]
        cs = colsum_ref[...].astype(jnp.float32)   # [1, TN]
        acc = acc_ref[...].astype(jnp.float32)
        o_ref[...] = ((sa * sw) * (acc + za * cs)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "tm", "tn", "tk",
                                             "interpret"))
def int4_matmul(act_codes: jnp.ndarray, act_scale: jnp.ndarray,
                act_zero: jnp.ndarray, w_packed: jnp.ndarray,
                w_scale: jnp.ndarray, *, out_dtype=jnp.float32,
                tm: int = 128, tn: int = 128, tk: int = 256,
                interpret: bool = True) -> jnp.ndarray:
    """act_codes [M, K] int8 (asym, [0, 15]); act_scale/zero [M, 1] f32;
    w_packed [K/2, N] uint8; w_scale [N] or [1, N] f32 → [M, N] out_dtype."""
    m, k = act_codes.shape
    k2, n = w_packed.shape
    if 2 * k2 != k:
        raise ValueError(f"packed K mismatch: acts K={k}, weights K={2 * k2}")
    w_scale = w_scale.reshape(1, n).astype(jnp.float32)

    tm = min(tm, max(8, m))
    tn, np_ = _tile_or_pad(n, tn)
    tk, kp = _tile_or_pad(k, tk, multiple=2)
    if np_ > n:
        # zero weight columns (and unit scales, so the 0·0 epilogue stays
        # finite); their outputs are sliced off below
        w_packed = jnp.pad(w_packed, ((0, 0), (0, np_ - n)))
        w_scale = jnp.pad(w_scale, ((0, 0), (0, np_ - n)),
                          constant_values=1)
    if kp > k:
        # zero activation codes against zero weight rows: 0·0 adds nothing
        # to the integer product, and zero rows leave the colsum
        # correction unchanged
        act_codes = jnp.pad(act_codes, ((0, 0), (0, kp - k)))
        w_packed = jnp.pad(w_packed, ((0, (kp - k) // 2), (0, 0)))

    # Precompute per-channel weight-code column sums (int32) for the
    # asymmetric-activation correction term (after padding — zero rows
    # are exact no-ops).
    lo = (w_packed & 0xF).astype(jnp.int32)
    hi = ((w_packed >> 4) & 0xF).astype(jnp.int32)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    colsum = (jnp.sum(lo, axis=0) + jnp.sum(hi, axis=0)).reshape(1, np_)

    pad_m = (-m) % tm
    if pad_m:
        act_codes = jnp.pad(act_codes, ((0, pad_m), (0, 0)))
        act_scale = jnp.pad(act_scale, ((0, pad_m), (0, 0)), constant_values=1)
        act_zero = jnp.pad(act_zero, ((0, pad_m), (0, 0)))
    mp = act_codes.shape[0]
    n_k = kp // tk

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        grid=(mp // tm, np_ // tn, n_k),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk // 2, tn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((tm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, tn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, tn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.int32)],
        interpret=interpret,
    )(act_codes, w_packed, act_scale, act_zero, w_scale, colsum)

    if pad_m or np_ > n:
        out = out[:m, :n]
    return out
