"""Pallas TPU kernel: online block-Hadamard rotation  X · (I_n ⊗ H_b).

TPU adaptation (see DESIGN.md §3): instead of the GPU butterfly FWHT, the
rotation is expressed as an MXU matmul against a block-diagonal expansion of
H_b held in VMEM:

  * b ≥ 128 : column tile TD = b, operand H_b directly (a [b, b] matmul).
  * b < 128 : column tile TD = 128 with operand I_{128/b} ⊗ H_b, so the MXU
    contraction is fully 128-aligned. The extra zeros are free — at b ≤ 128
    the op is memory-bound (arithmetic intensity TD/2 FLOP/byte < the v5e
    ridge ≈ 240), so MXU padding costs no wall-clock.

The grid walks (row tiles × column tiles); each kernel instance loads one
[TM, TD] activation tile plus the [TD, TD] rotation operand and performs a
single dot. Rows are padded to the row tile; the rotation operand is built
once per (b, TD) at trace time.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.hadamard import hadamard

__all__ = ["block_hadamard", "rotation_operand", "DEFAULT_ROW_TILE"]

DEFAULT_ROW_TILE = 256
_LANE = 128  # TPU lane width / MXU edge


@functools.lru_cache(maxsize=None)
def _rotation_operand_np(b: int, td: int) -> np.ndarray:
    """I_{td/b} ⊗ H_b / √b as float32, td a multiple of b."""
    hb = hadamard(b).astype(np.float32) / math.sqrt(b)
    reps = td // b
    if reps == 1:
        return hb
    return np.kron(np.eye(reps, dtype=np.float32), hb)


def rotation_operand(b: int, td: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.asarray(_rotation_operand_np(b, td), dtype=dtype)


def _kernel(x_ref, h_ref, o_ref):
    x = x_ref[...]
    h = h_ref[...]
    y = jax.lax.dot_general(
        x.astype(jnp.float32), h,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = y.astype(o_ref.dtype)


def _column_tile(b: int, d: int) -> int:
    """Smallest multiple of b that divides d and is ≥ the 128 lane width
    (bounded by 2048 to cap the VMEM operand at 16 MiB f32)."""
    n = d // b
    best = b
    for m in range(1, n + 1):
        if n % m:
            continue
        td = b * m
        if td > 2048:
            break
        best = td
        if td >= _LANE:
            break
    return best


@functools.partial(jax.jit, static_argnames=("b", "row_tile", "interpret"))
def block_hadamard(x: jnp.ndarray, b: int, *, row_tile: int = DEFAULT_ROW_TILE,
                   interpret: bool = True) -> jnp.ndarray:
    """Apply the normalized block rotation over the last axis of x [..., D].

    interpret=True runs the kernel body in Python (CPU validation); on TPU
    pass interpret=False for the compiled Mosaic kernel.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    if d % b:
        raise ValueError(f"feature dim {d} not divisible by block size {b}")
    m = int(np.prod(orig_shape[:-1])) if len(orig_shape) > 1 else 1
    x2 = x.reshape(m, d)

    td = _column_tile(b, d)
    tm = min(row_tile, max(8, m))
    pad_m = (-m) % tm
    if pad_m:
        x2 = jnp.pad(x2, ((0, pad_m), (0, 0)))
    mp = x2.shape[0]

    h_op = rotation_operand(b, td, dtype=jnp.float32)

    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((mp, d), x.dtype),
        grid=(mp // tm, d // td),
        in_specs=[
            pl.BlockSpec((tm, td), lambda i, j: (i, j)),
            pl.BlockSpec((td, td), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, td), lambda i, j: (i, j)),
        interpret=interpret,
    )(x2, h_op)

    if pad_m:
        out = out[:m]
    return out.reshape(orig_shape)
