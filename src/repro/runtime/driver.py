"""Fault-tolerant training driver: retries, checkpoint/restart, straggler
detection, elastic rescale.

Designed for thousands of nodes, validated here at CPU scale:

  * **Failures** — every step runs under a retry guard; transient device
    errors re-execute the step, persistent ones trigger restore-from-last-
    checkpoint (a step is only "committed" once its effects are reproducible
    from the checkpoint lineage — the data iterator is seeded by step, so
    replays are deterministic).
  * **Stragglers** — per-step wall times feed an EWMA; steps slower than
    `straggler_factor ×` the EWMA are recorded and, past a threshold rate,
    the driver requests a rescale (in a real deployment this feeds the pod
    scheduler; here it flips the mesh to the next smaller data extent).
  * **Elastic rescale** — `ElasticMesh.next_smaller()` recomputes a mesh
    from the surviving device count; parameters are restored with the new
    shardings via `CheckpointManager.restore_sharded`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager

Params = Any


@dataclasses.dataclass
class RuntimeConfig:
    checkpoint_every: int = 50
    max_retries: int = 2
    straggler_factor: float = 2.5
    straggler_window: int = 20
    ewma_alpha: float = 0.1


@dataclasses.dataclass
class StepStats:
    ewma: float = 0.0
    count: int = 0
    stragglers: list[int] = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float, factor: float, alpha: float) -> bool:
        is_straggler = self.count > 5 and dt > factor * self.ewma
        self.ewma = dt if self.count == 0 else \
            (1 - alpha) * self.ewma + alpha * dt
        self.count += 1
        if is_straggler:
            self.stragglers.append(step)
        return is_straggler


class ElasticMesh:
    """Mesh sizing policy: given n devices, the largest (data, model) grid
    with the model extent fixed (TP degree is architecture-bound; DP is the
    elastic dimension)."""

    def __init__(self, model_parallel: int):
        self.model_parallel = model_parallel

    def shape_for(self, n_devices: int) -> tuple[int, int]:
        data = max(1, n_devices // self.model_parallel)
        # largest power-of-2 data extent (keeps batch divisible on rescale)
        p = 1
        while p * 2 <= data:
            p *= 2
        return (p, self.model_parallel)

    def make(self, devices=None):
        devices = devices if devices is not None else jax.devices()
        shape = self.shape_for(len(devices))
        n = shape[0] * shape[1]
        dev = np.asarray(devices[:n]).reshape(shape)
        return jax.sharding.Mesh(dev, ("data", "model"))


class TrainDriver:
    def __init__(self, train_step: Callable, ckpt: CheckpointManager,
                 cfg: RuntimeConfig):
        self.train_step = train_step
        self.ckpt = ckpt
        self.cfg = cfg
        self.stats = StepStats()
        self.failures = 0
        self.restores = 0

    def run(self, params: Params, opt_state: Params,
            batches: Iterator, *, start_step: int = 0, num_steps: int = 100,
            on_metrics: Callable | None = None):
        step = start_step
        state = (params, opt_state)
        committed = start_step
        while step < start_step + num_steps:
            batch = next(batches)
            t0 = time.perf_counter()
            try:
                state = self._guarded_step(state, batch)
            except Exception:
                # persistent failure: restore last committed checkpoint
                self.restores += 1
                target = {"params": state[0], "opt": state[1]}
                restored = self.ckpt.restore(target=target)
                state = (restored["params"], restored["opt"])
                step = committed
                continue
            dt = time.perf_counter() - t0
            self.stats.record(step, dt, self.cfg.straggler_factor,
                              self.cfg.ewma_alpha)
            step += 1
            if step % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step, {"params": state[0], "opt": state[1]})
                committed = step
            if on_metrics:
                on_metrics(step, state)
        self.ckpt.save(step, {"params": state[0], "opt": state[1]},
                       blocking=True)
        return state, step

    def _guarded_step(self, state, batch):
        last = None
        for _ in range(self.cfg.max_retries + 1):
            try:
                params, opt_state, metrics = self.train_step(
                    state[0], state[1], batch)
                # commit: block until the step really finished
                jax.block_until_ready(metrics.get("loss", params))
                return (params, opt_state)
            except Exception as e:  # noqa: BLE001 — retry any device error
                self.failures += 1
                last = e
        raise last

    @property
    def straggler_rate(self) -> float:
        if not self.stats.count:
            return 0.0
        return len(self.stats.stragglers) / self.stats.count

    def should_rescale(self) -> bool:
        recent = [s for s in self.stats.stragglers
                  if s >= self.stats.count - self.cfg.straggler_window]
        return len(recent) > self.cfg.straggler_window // 4
