"""Mixture-of-Experts feed-forward: shared (always-on) + routed fine-grained
experts (DeepSeekMoE / Llama4) with capacity-bounded top-k routing.

Production dispatch is **sort/gather-based** (MegaBlocks/MaxText style), not
the classic GShard one-hot einsum: the einsum dispatch costs
O(T·E·C·d) FLOPs — at train_4k scale that is ~100× the expert FFN itself —
while gather dispatch moves O(E·C·d) bytes with zero matmul FLOPs.

Routing is performed **per batch row** so that, with the batch sharded over
('pod','data') and seq replicated, every sort/gather/scatter is device-local;
the only cross-device movement is the expert-dim all-to-all implied by the
expert FFN einsum (experts sharded on 'model'), which is exactly the
communication MoE fundamentally requires.

`moe_ffn_dense_oracle` evaluates every expert for every token (no capacity)
— the exact reference used by the tests.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.context import shard_act

Params = dict[str, Any]


def init_moe(key, d_model: int, n_experts: int, moe_d_ff: int,
             n_shared: int, act: str, dtype) -> Params:
    ks = jax.random.split(key, 7)
    sc_in = 1.0 / math.sqrt(d_model)
    sc_out = 1.0 / math.sqrt(moe_d_ff)
    p = {
        "router": (jax.random.normal(ks[0], (d_model, n_experts))
                   * sc_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (n_experts, d_model, moe_d_ff))
                   * sc_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (n_experts, d_model, moe_d_ff))
                 * sc_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (n_experts, moe_d_ff, d_model))
                   * sc_out).astype(dtype),
    }
    if n_shared:
        sf = n_shared * moe_d_ff
        p["shared_gate"] = (jax.random.normal(ks[4], (d_model, sf))
                            * sc_in).astype(dtype)
        p["shared_up"] = (jax.random.normal(ks[5], (d_model, sf))
                          * sc_in).astype(dtype)
        p["shared_down"] = (jax.random.normal(ks[6], (sf, d_model))
                            * (1.0 / math.sqrt(sf))).astype(dtype)
    return p


def _route(xt: jnp.ndarray, router: jnp.ndarray, top_k: int):
    """xt: [B, S, d] → (gates [B,S,k] renormalized, idx [B,S,k])."""
    logits = xt.astype(jnp.float32) @ router
    gates = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(gates, top_k)
    vals = vals / jnp.maximum(jnp.sum(vals, -1, keepdims=True), 1e-9)
    return vals, idx


def _expert_ffn(expert_in: jnp.ndarray, p: Params, act: str,
                down_proj_fn=None, act_in=None) -> jnp.ndarray:
    """expert_in: [B, E, C, d] → [B, E, C, d] through per-expert SwiGLU."""
    if act_in is not None:
        expert_in = act_in(expert_in, "expert_in")
    if act == "silu":
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", expert_in, p["w_gate"])) \
            * jnp.einsum("becd,edf->becf", expert_in, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", expert_in, p["w_up"]))
    h = shard_act(h, ("batch", "experts", None, "expert_mlp"))
    if down_proj_fn is not None:
        out = down_proj_fn(h, p["w_down"])
    else:
        out = jnp.einsum("becf,efd->becd", h, p["w_down"])
    return shard_act(out, ("batch", "experts", None, "embed"))


def moe_ffn(x: jnp.ndarray, p: Params, *, n_experts: int, top_k: int,
            capacity_factor: float, act: str,
            down_proj_fn=None, act_in=None,
            shared_down_proj_fn=None) -> jnp.ndarray:
    """Gather-dispatch MoE. x: [B, S, d] → [B, S, d]."""
    if act_in is not None:
        x = act_in(x, "ffn")
    b, s, d = x.shape
    e = n_experts
    c = max(1, int(math.ceil(s * top_k / e * capacity_factor)))

    gates, idx = _route(x, p["router"], top_k)              # [B,S,k]
    sk = s * top_k
    flat_e = idx.reshape(b, sk)                              # expert of slot
    flat_g = gates.reshape(b, sk)

    def dispatch_row(fe, fg):
        """Per-row slot→(expert,capacity) assignment. vmapped over the
        batch so the sort/scatter/gather all carry an explicit batch dim —
        GSPMD then keeps them batch-sharded (an advanced-index scatter with
        an iota row index replicates instead; §Perf cell A)."""
        order = jnp.argsort(fe, stable=True)
        se = fe[order]
        sg = fg[order]
        stok = order // top_k                               # token of slot
        counts = jnp.sum(jax.nn.one_hot(se, e, dtype=jnp.int32), axis=0)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(sk) - starts[se]
        keep = pos < c
        dest = jnp.where(keep, se * c + pos, e * c)         # overflow bucket
        tok_grid = jnp.zeros((e * c + 1,), jnp.int32).at[dest].set(
            stok, mode="drop")[: e * c]
        gate_grid = jnp.zeros((e * c + 1,), jnp.float32).at[dest].set(
            jnp.where(keep, sg, 0.0), mode="drop")[: e * c]
        return tok_grid, gate_grid

    tok_grid, gate_grid = jax.vmap(dispatch_row)(flat_e, flat_g)

    # gather token features into expert-major layout (batched gather)
    expert_in = jnp.take_along_axis(x, tok_grid[..., None], axis=1)
    expert_in = expert_in.reshape(b, e, c, d)
    expert_in = expert_in * (gate_grid.reshape(b, e, c, 1) != 0)
    expert_in = shard_act(expert_in, ("batch", "experts", None, "embed"))

    expert_out = _expert_ffn(expert_in, p, act, down_proj_fn, act_in)

    # combine: weighted scatter-add back to token positions (batched)
    weighted = expert_out.reshape(b, e * c, d) * \
        gate_grid[..., None].astype(expert_out.dtype)

    def combine_row(w_row, tok_row):
        return jnp.zeros((s, d), x.dtype).at[tok_row].add(
            w_row.astype(x.dtype))

    out = jax.vmap(combine_row)(weighted, tok_grid)

    if "shared_gate" in p:
        sh = jax.nn.silu(x @ p["shared_gate"]) * (x @ p["shared_up"])
        if shared_down_proj_fn is not None:
            out = out + shared_down_proj_fn(sh, p["shared_down"])
        else:
            out = out + sh @ p["shared_down"]
    return out


def moe_ffn_dense_oracle(x: jnp.ndarray, p: Params, *, n_experts: int,
                         top_k: int, act: str,
                         down_proj_fn=None, act_in=None,
                         shared_down_proj_fn=None) -> jnp.ndarray:
    """Reference: evaluate EVERY expert for every token, mix by top-k gates
    (no capacity drops). O(E·FFN), but per-token exact and therefore
    chunking-invariant — the parity oracle for serving tests (capacity
    drops in `moe_ffn` depend on the chunk length, so chunked prefill and
    a whole-prompt pass route differently there). Takes the same PTQ hooks
    as `moe_ffn` (the routed down-proj einsum is shape-generic over the
    capacity vs sequence axis)."""
    if act_in is not None:
        x = act_in(x, "ffn")
    b, s, d = x.shape
    gates, idx = _route(x, p["router"], top_k)
    xe = x[:, None].repeat(n_experts, 1)                     # [B,E,S,d]
    if act_in is not None:
        xe = act_in(xe, "expert_in")
    if act == "silu":
        h = jax.nn.silu(jnp.einsum("besd,edf->besf", xe, p["w_gate"])) \
            * jnp.einsum("besd,edf->besf", xe, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("besd,edf->besf", xe, p["w_up"]))
    if down_proj_fn is not None:
        allout = down_proj_fn(h, p["w_down"])                # [B,E,S,d]
    else:
        allout = jnp.einsum("besf,efd->besd", h, p["w_down"])
    onehot = jax.nn.one_hot(idx, n_experts, dtype=x.dtype)   # [B,S,k,E]
    mix = jnp.einsum("bske,bsk->bse", onehot, gates.astype(x.dtype))
    out = jnp.einsum("bse,besd->bsd", mix, allout)
    if "shared_gate" in p:
        sh = jax.nn.silu(x @ p["shared_gate"]) * (x @ p["shared_up"])
        if shared_down_proj_fn is not None:
            out = out + shared_down_proj_fn(sh, p["shared_down"])
        else:
            out = out + sh @ p["shared_down"]
    return out
