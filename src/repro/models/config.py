"""Unified architecture configuration for the model zoo.

One `ArchConfig` describes every family in the assigned pool: dense decoder
LMs (GQA/RoPE/SwiGLU), MoE (shared + routed fine-grained experts), pure SSM
(Mamba2/SSD), hybrid (Mamba2 backbone + shared attention block), encoder-only
(audio backbone), and VLM backbones (vision-patch frontend stub).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encoder", "vlm"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    vocab: int
    # attention (unused for pure-ssm layers)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True
    # feed-forward
    d_ff: int = 0
    act: str = "silu"            # "silu" (SwiGLU) | "gelu" (classic MLP)
    norm: str = "rmsnorm"        # "rmsnorm" | "layernorm"
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # hybrid: one shared attention(+FFN) block applied every `period` SSM layers
    hybrid_period: int = 0
    # modality frontend stub (input_specs provides precomputed embeddings)
    frontend: str | None = None  # None | "audio_frames" | "vision_patches"
    frontend_tokens: int = 0     # prefix length supplied by the stub (vlm)
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # notes for DESIGN/dry-run bookkeeping
    notes: str = ""

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM state or hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        """Encoder-only architectures have no autoregressive decode."""
        return self.family != "encoder"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def uses_moe(self) -> bool:
        return self.n_experts > 0

    def validate(self) -> "ArchConfig":
        if self.family in ("dense", "moe", "encoder", "vlm", "hybrid"):
            assert self.n_heads > 0 and self.head_dim > 0
            assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0
            assert self.d_inner % self.ssm_head_dim == 0
        if self.uses_moe:
            assert self.top_k > 0 and self.moe_d_ff > 0
        return self

    def reduced(self, **overrides) -> "ArchConfig":
        """A small same-family config for CPU smoke tests."""
        base = dict(
            n_layers=min(self.n_layers, 2),
            d_model=128,
            vocab=min(self.vocab, 512),
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32 if self.head_dim else 0,
            d_ff=256 if self.d_ff else 0,
            n_experts=min(self.n_experts, 8),
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2),
            moe_d_ff=64 if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=32,
            hybrid_period=min(self.hybrid_period, 2),
            frontend_tokens=min(self.frontend_tokens, 16),
            param_dtype="float32",
            compute_dtype="float32",
            name=self.name + "-smoke",
        )
        base.update(overrides)
        return dataclasses.replace(self, **base).validate()


# ---------------------------------------------------------------------------
# Input-shape cells (assigned per-arch; see system spec)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeCell("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeCell("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeCell("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeCell("long_500k", 524_288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def applicable_shapes(cfg: ArchConfig) -> list[ShapeCell]:
    """Shape cells that are well-defined for this architecture.

    Skips (recorded in DESIGN.md §Arch-applicability):
      * decode shapes for encoder-only archs (no autoregressive step),
      * long_500k for pure full-attention archs (needs sub-quadratic decode).
    """
    cells = [TRAIN_4K, PREFILL_32K]
    if cfg.has_decode:
        cells.append(DECODE_32K)
        if cfg.subquadratic:
            cells.append(LONG_500K)
    return cells


def skipped_shapes(cfg: ArchConfig) -> dict[str, str]:
    out = {}
    if not cfg.has_decode:
        out["decode_32k"] = "encoder-only: no autoregressive decode step"
        out["long_500k"] = "encoder-only: no autoregressive decode step"
    elif not cfg.subquadratic:
        out["long_500k"] = ("pure full-attention arch: 500k decode needs "
                            "sub-quadratic attention (run for ssm/hybrid only)")
    return out
