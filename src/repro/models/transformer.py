"""The unified model: dense / MoE / SSM / hybrid / encoder / VLM families.

Parameters are plain dict pytrees with per-layer leaves stacked on axis 0 so
the layer stack is a `lax.scan` (compact HLO — essential for compiling 62-layer
models in the dry-run). The PTQ pipeline walks the same tree to merge
permutations/rotations and swap in quantized projections.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed.context import shard_act
from . import layers as L
from . import moe as M
from . import ssm as S
from .config import ArchConfig, ShapeCell

Params = dict[str, Any]

FRONTEND_DIMS = {"audio_frames": 512, "vision_patches": 1024}


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


class Model:
    """Functional model wrapper for one ArchConfig."""

    def __init__(self, cfg: ArchConfig, *, quant_hooks=None,
                 remat_policy: str = "nothing",
                 moe_dense_oracle: bool = False):
        self.cfg = cfg.validate()
        self.pdt = _dtype(cfg.param_dtype)
        self.cdt = _dtype(cfg.compute_dtype)
        # quant_hooks: {"down_proj_fn": fn(h, w)->out, "act_in_fn": fn(x)->x}
        self.quant_hooks = quant_hooks or {}
        # moe_dense_oracle: route MoE FFNs through the evaluate-all-experts
        # oracle (per-token exact, chunking-invariant) instead of the
        # capacity-bounded gather dispatch — parity tests only, where
        # chunk-length-dependent capacity drops would break chunked-prefill
        # ≡ whole-prompt comparisons
        self.moe_dense_oracle = moe_dense_oracle
        # remat_policy: "nothing" saves only layer boundaries (min memory,
        # max recompute — the backward re-runs the layer INCLUDING its
        # ZeRO-3 weight all-gathers); "dots" saves matmul outputs, which
        # keeps the recompute (and crucially the re-gathers) out of the
        # backward at ~2 GiB/device of extra activations (§Perf, cell A).
        self.remat_policy = remat_policy
        if cfg.n_heads:
            self.attn_spec = L.AttnSpec(
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim, causal=cfg.causal,
                rope_theta=cfg.rope_theta, qkv_bias=cfg.qkv_bias)

    # ------------------------------------------------------------------
    # Init
    # ------------------------------------------------------------------

    def _init_block(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        if cfg.family == "ssm":
            return {
                "norm": L.init_norm(cfg.d_model, cfg.norm, self.pdt),
                "ssm": S.init_ssm(ks[0], cfg.d_model, expand=cfg.ssm_expand,
                                  head_dim=cfg.ssm_head_dim,
                                  state=cfg.ssm_state,
                                  conv_width=cfg.ssm_conv_width,
                                  dtype=self.pdt),
            }
        if cfg.family == "hybrid":
            return {
                "norm": L.init_norm(cfg.d_model, cfg.norm, self.pdt),
                "ssm": S.init_ssm(ks[0], cfg.d_model, expand=cfg.ssm_expand,
                                  head_dim=cfg.ssm_head_dim,
                                  state=cfg.ssm_state,
                                  conv_width=cfg.ssm_conv_width,
                                  dtype=self.pdt),
            }
        blk = {
            "attn_norm": L.init_norm(cfg.d_model, cfg.norm, self.pdt),
            "attn": L.init_attention(ks[0], cfg.d_model, self.attn_spec,
                                     self.pdt),
            "ffn_norm": L.init_norm(cfg.d_model, cfg.norm, self.pdt),
        }
        if cfg.uses_moe:
            blk["moe"] = M.init_moe(ks[1], cfg.d_model, cfg.n_experts,
                                    cfg.moe_d_ff, cfg.n_shared_experts,
                                    cfg.act, self.pdt)
        else:
            blk["ffn"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act,
                                    self.pdt)
        return blk

    def _shared_attn_block(self, key) -> Params:
        """Hybrid (Zamba2): one shared attention+FFN block."""
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        return {
            "attn_norm": L.init_norm(cfg.d_model, cfg.norm, self.pdt),
            "attn": L.init_attention(ks[0], cfg.d_model, self.attn_spec,
                                     self.pdt),
            "ffn_norm": L.init_norm(cfg.d_model, cfg.norm, self.pdt),
            "ffn": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act,
                              self.pdt),
        }

    def init(self, key) -> Params:
        cfg = self.cfg
        k_emb, k_layers, k_head, k_shared, k_fe = jax.random.split(key, 5)
        p: Params = {}
        if cfg.frontend != "audio_frames":
            p["embed"] = (jax.random.normal(k_emb, (cfg.vocab, cfg.d_model))
                          * 0.02).astype(self.pdt)
        if cfg.frontend is not None:
            fdim = FRONTEND_DIMS[cfg.frontend]
            p["frontend_proj"] = (jax.random.normal(k_fe, (fdim, cfg.d_model))
                                  * (fdim ** -0.5)).astype(self.pdt)
        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        blocks = [self._init_block(k) for k in layer_keys]
        p["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
        if cfg.family == "hybrid":
            p["shared_attn"] = self._shared_attn_block(k_shared)
        p["final_norm"] = L.init_norm(cfg.d_model, cfg.norm, self.pdt)
        p["lm_head"] = (jax.random.normal(k_head, (cfg.d_model, cfg.vocab))
                        * (cfg.d_model ** -0.5)).astype(self.pdt)
        return p

    def init_abstract(self) -> Params:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ------------------------------------------------------------------
    # Blocks
    # ------------------------------------------------------------------

    def _apply_block(self, x, blk: Params, cache, cache_index, *,
                     positions=None, block_table=None, seq_lengths=None,
                     register_index=None, valid_len=None):
        cfg = self.cfg
        hooks = self.quant_hooks
        new_cache = None
        if cfg.family in ("ssm", "hybrid"):
            h = L.apply_norm(x, blk["norm"], cfg.norm)
            h, new_cache = S.ssm_block(
                h, blk["ssm"], head_dim=cfg.ssm_head_dim, state=cfg.ssm_state,
                chunk=cfg.ssm_chunk, cache=cache, cache_index=cache_index,
                register_index=register_index, valid_len=valid_len,
                act_in=hooks.get("act_in"),
                out_proj_fn=hooks.get("ssm_out_proj_fn"))
            return x + h, new_cache

        h = L.apply_norm(x, blk["attn_norm"], cfg.norm)
        h, attn_cache = L.attention(h, blk["attn"], self.attn_spec,
                                    positions=positions, cache=cache,
                                    cache_index=cache_index,
                                    block_table=block_table,
                                    seq_lengths=seq_lengths,
                                    act_in=hooks.get("act_in"))
        x = x + h
        h = L.apply_norm(x, blk["ffn_norm"], cfg.norm)
        if cfg.uses_moe:
            if self.moe_dense_oracle:
                h = M.moe_ffn_dense_oracle(
                    h, blk["moe"], n_experts=cfg.n_experts, top_k=cfg.top_k,
                    act=cfg.act, down_proj_fn=hooks.get("moe_down_proj_fn"),
                    act_in=hooks.get("act_in"),
                    shared_down_proj_fn=hooks.get("down_proj_fn"))
            else:
                h = M.moe_ffn(h, blk["moe"], n_experts=cfg.n_experts,
                              top_k=cfg.top_k,
                              capacity_factor=cfg.capacity_factor,
                              act=cfg.act,
                              down_proj_fn=hooks.get("moe_down_proj_fn"),
                              act_in=hooks.get("act_in"),
                              shared_down_proj_fn=hooks.get("down_proj_fn"))
        else:
            h = L.mlp(h, blk["ffn"], cfg.act,
                      down_proj_fn=hooks.get("down_proj_fn"),
                      act_in=hooks.get("act_in"))
        return x + h, attn_cache

    def _apply_shared(self, x, shared: Params, cache, cache_index, *,
                      block_table=None, seq_lengths=None):
        cfg = self.cfg
        hooks = self.quant_hooks
        h = L.apply_norm(x, shared["attn_norm"], cfg.norm)
        h, attn_cache = L.attention(h, shared["attn"], self.attn_spec,
                                    cache=cache, cache_index=cache_index,
                                    block_table=block_table,
                                    seq_lengths=seq_lengths,
                                    act_in=hooks.get("act_in"))
        x = x + h
        h = L.apply_norm(x, shared["ffn_norm"], cfg.norm)
        h = L.mlp(h, shared["ffn"], cfg.act,
                  down_proj_fn=hooks.get("down_proj_fn"),
                  act_in=hooks.get("act_in"))
        return x + h, attn_cache

    def _run_layers_unrolled(self, params, x):
        """Python-loop execution (no scan) — used by PTQ calibration so the
        capture hook can record per-layer activations via side effects."""
        cfg = self.cfg
        lp = params["layers"]

        def layer_slice(i):
            return jax.tree.map(lambda a: a[i], lp)

        if cfg.family == "hybrid":
            n_groups, period, _ = self._hybrid_groups()
            for i in range(cfg.n_layers):
                x, _ = self._apply_block(x, layer_slice(i), None, None)
                if (i + 1) % period == 0 and (i + 1) // period <= n_groups:
                    x, _ = self._apply_shared(x, params["shared_attn"],
                                              None, None)
            return x
        for i in range(cfg.n_layers):
            x, _ = self._apply_block(x, layer_slice(i), None, None)
        return x

    def _hybrid_groups(self) -> tuple[int, int, int]:
        """(n_groups, period, tail): L = n_groups·period + tail; the shared
        attention block runs after each full group."""
        cfg = self.cfg
        period = cfg.hybrid_period or cfg.n_layers
        n_groups = cfg.n_layers // period
        tail = cfg.n_layers - n_groups * period
        return n_groups, period, tail

    # ------------------------------------------------------------------
    # Forward
    # ------------------------------------------------------------------

    def _embed_inputs(self, params: Params, batch: Params):
        cfg = self.cfg
        if cfg.frontend == "audio_frames":
            x = batch["frames"].astype(self.cdt) @ params["frontend_proj"]
        elif cfg.frontend == "vision_patches":
            pe = batch["patches"].astype(self.cdt) @ params["frontend_proj"]
            te = jnp.take(params["embed"], batch["tokens"], axis=0)
            x = jnp.concatenate([pe, te.astype(self.cdt)], axis=1)
        else:
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = x.astype(self.cdt)
        return shard_act(x, ("batch", "seq", "embed"))

    def _run_layers(self, params, x, *, caches=None, cache_index=None,
                    block_table=None, seq_lengths=None, register_index=None,
                    valid_len=None, remat: bool = False):
        cfg = self.cfg

        def body(carry, inp):
            blk, cache = inp
            y, new_cache = self._apply_block(carry, blk, cache, cache_index,
                                             block_table=block_table,
                                             seq_lengths=seq_lengths,
                                             register_index=register_index,
                                             valid_len=valid_len)
            return y, new_cache

        if remat:
            policy = {
                "nothing": jax.checkpoint_policies.nothing_saveable,
                "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            }[self.remat_policy]
            body = jax.checkpoint(body, policy=policy)

        if cfg.family == "hybrid":
            n_groups, period, tail = self._hybrid_groups()
            lp = params["layers"]
            main = jax.tree.map(
                lambda a: a[: n_groups * period].reshape(
                    n_groups, period, *a.shape[1:]), lp)
            tail_p = jax.tree.map(lambda a: a[n_groups * period:], lp)
            c_main = c_tail = c_shared = None
            if caches is not None:
                c_main = jax.tree.map(
                    lambda a: a[: n_groups * period].reshape(
                        n_groups, period, *a.shape[1:]), caches["ssm"])
                c_tail = jax.tree.map(lambda a: a[n_groups * period:],
                                      caches["ssm"])
                c_shared = caches["shared"]

            def group_body(carry, inp):
                gp, gcache, shared_cache = inp
                y, new_c = jax.lax.scan(body, carry, (gp, gcache))
                y, new_sc = self._apply_shared(y, params["shared_attn"],
                                               shared_cache, cache_index,
                                               block_table=block_table,
                                               seq_lengths=seq_lengths)
                return y, (new_c, new_sc)

            if caches is None:
                def group_body_nc(carry, gp):
                    y, _ = jax.lax.scan(lambda c, b: body(c, (b, None)),
                                        carry, gp)
                    y, _ = self._apply_shared(y, params["shared_attn"], None,
                                              None)
                    return y, None
                x, _ = jax.lax.scan(group_body_nc, x, main)
                if tail:
                    x, _ = jax.lax.scan(lambda c, b: body(c, (b, None)), x,
                                        tail_p)
                return x, None
            else:
                x, (nc_main, nc_shared) = jax.lax.scan(
                    group_body, x, (main, c_main, c_shared))
                nc_main = jax.tree.map(
                    lambda a: a.reshape(n_groups * period, *a.shape[2:]),
                    nc_main)
                nc_tail = None
                if tail:
                    x, nc_tail = jax.lax.scan(body, x, (tail_p, c_tail))
                    nc_main = jax.tree.map(
                        lambda a, t: jnp.concatenate([a, t], 0),
                        nc_main, nc_tail)
                return x, {"ssm": nc_main, "shared": nc_shared}

        if caches is None:
            x, _ = jax.lax.scan(lambda c, b: body(c, (b, None)), x,
                                params["layers"])
            return x, None
        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
        return x, new_caches

    def forward(self, params: Params, batch: Params, *,
                remat: bool = False, unroll: bool = False) -> jnp.ndarray:
        """Full-sequence forward → logits [B, S, vocab]. `unroll=True` runs
        the layer stack as a Python loop (PTQ calibration capture)."""
        x = self._embed_inputs(params, batch)
        if unroll:
            x = self._run_layers_unrolled(params, x)
        else:
            x, _ = self._run_layers(params, x, remat=remat)
        x = L.apply_norm(x, params["final_norm"], self.cfg.norm)
        logits = x @ params["lm_head"].astype(self.cdt)
        return shard_act(logits, ("batch", "seq", "vocab"))

    def loss_fn(self, params: Params, batch: Params, *,
                remat: bool = False):
        """Mean next-token (or frame-label) cross-entropy + z-loss."""
        cfg = self.cfg
        logits = self.forward(params, batch, remat=remat).astype(jnp.float32)
        labels = batch["labels"]
        if cfg.frontend == "vision_patches":
            # labels cover text positions only (after the patch prefix)
            logits = logits[:, -labels.shape[1]:]
        mask = (labels >= 0).astype(jnp.float32)
        labels = jnp.maximum(labels, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(nll) / denom
        zloss = 1e-4 * jnp.sum((lse * mask) ** 2) / denom
        return loss + zloss, {"nll": loss, "zloss": zloss,
                              "tokens": jnp.sum(mask)}

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Params:
        cfg = self.cfg
        if cfg.family == "ssm":
            one = S.init_ssm_cache(batch, cfg.d_model, expand=cfg.ssm_expand,
                                   head_dim=cfg.ssm_head_dim,
                                   state=cfg.ssm_state,
                                   conv_width=cfg.ssm_conv_width, dtype=dtype)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), one)
        if cfg.family == "hybrid":
            one = S.init_ssm_cache(batch, cfg.d_model, expand=cfg.ssm_expand,
                                   head_dim=cfg.ssm_head_dim,
                                   state=cfg.ssm_state,
                                   conv_width=cfg.ssm_conv_width, dtype=dtype)
            ssm_c = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), one)
            n_groups, _, _ = self._hybrid_groups()
            ac = L.init_attention_cache(batch, max_len, self.attn_spec, dtype)
            shared_c = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_groups, *a.shape)), ac)
            return {"ssm": ssm_c, "shared": shared_c}
        one = L.init_attention_cache(batch, max_len, self.attn_spec, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), one)

    def init_paged_state(self, n_pages: int, page_size: int, n_slots: int,
                         dtype=jnp.bfloat16) -> Params:
        """Engine-owned partitioned state `{"kv": ..., "register": ...}`.

        kv leaves are page pools ([n_layers/n_groups, n_pages, page_size,
        ...], block-table-indexed); register leaves are slot pools
        ([n_layers, n_slots, ...], one fixed slot per admitted sequence).
        Dense/MoE state is pure kv, pure SSM is pure register, hybrid
        mixes both kinds.
        """
        cfg = self.cfg

        def stack(one, n):
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n, *a.shape)), one)

        def ssm_slots():
            return stack(S.init_ssm_cache(
                n_slots, cfg.d_model, expand=cfg.ssm_expand,
                head_dim=cfg.ssm_head_dim, state=cfg.ssm_state,
                conv_width=cfg.ssm_conv_width, dtype=dtype), cfg.n_layers)

        if cfg.family == "ssm":
            return {"kv": {}, "register": ssm_slots()}
        if cfg.family == "hybrid":
            n_groups, _, _ = self._hybrid_groups()
            shared = stack(L.init_attention_cache(
                n_pages, page_size, self.attn_spec, dtype), n_groups)
            return {"kv": {"shared": shared}, "register": {"ssm": ssm_slots()}}
        return {"kv": self.init_cache(n_pages, page_size, dtype),
                "register": {}}

    def prefill(self, params: Params, batch: Params, caches: Params):
        """Process the prompt, fill caches, return last-position logits."""
        x = self._embed_inputs(params, batch)
        x, new_caches = self._run_layers(params, x, caches=caches,
                                         cache_index=jnp.asarray(0, jnp.int32))
        x = L.apply_norm(x[:, -1:], params["final_norm"], self.cfg.norm)
        logits = x @ params["lm_head"].astype(self.cdt)
        return logits[:, 0], new_caches

    def forward_chunk(self, params: Params, tokens: jnp.ndarray,
                      caches: Params, index: jnp.ndarray,
                      block_table: jnp.ndarray | None = None,
                      seq_lengths: jnp.ndarray | None = None,
                      register_index: jnp.ndarray | None = None):
        """Token chunk [B, S] at fill position `index` → per-position
        logits [B, S, V] + updated caches.

        The serving-engine entry point: S == 1 with a vector index is a
        per-slot continuous-batching decode step; S > 1 with a scalar
        index is one chunk of an incremental (chunked) prefill, causal
        within the chunk and attending to everything already cached. With
        `block_table` [B, P], kv leaves of `caches` are the engine's page
        pool ([n_layers, n_pages, page_size, ...]) and attention runs
        block-table-native — new rows are written straight into their
        pages and the paged-attention kernel walks the table;
        `seq_lengths` [B] (the true per-sequence context lengths, 0 for
        padded batch rows) feed the kernel's ragged early-exit. With
        `register_index` [B], SSM leaves of `caches` are register slot
        pools ([n_layers, n_slots, ...]) gathered/scattered by slot, and
        `seq_lengths` additionally bound each prefill row's live tokens so
        right-padded chunk tails stay out of the carried state.
        """
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.cdt)
        x = shard_act(x, ("batch", "seq", "embed"))
        valid_len = None
        if register_index is not None and seq_lengths is not None \
                and tokens.shape[1] > 1:
            # prefill chunk: index is the scalar fill position, so the
            # row's live tokens in THIS chunk end at seq_lengths - index
            valid_len = seq_lengths - jnp.asarray(index, jnp.int32)
        x, new_caches = self._run_layers(params, x, caches=caches,
                                         cache_index=index,
                                         block_table=block_table,
                                         seq_lengths=seq_lengths,
                                         register_index=register_index,
                                         valid_len=valid_len)
        x = L.apply_norm(x, params["final_norm"], self.cfg.norm)
        logits = x @ params["lm_head"].astype(self.cdt)
        return logits, new_caches

    def decode_step(self, params: Params, tokens: jnp.ndarray,
                    caches: Params, index: jnp.ndarray):
        """One decode step. tokens: [B, 1]; index: scalar int32 fill pos."""
        logits, new_caches = self.forward_chunk(params, tokens, caches, index)
        return logits[:, 0], new_caches


def build_model(cfg: ArchConfig, **kw) -> Model:
    return Model(cfg, **kw)
