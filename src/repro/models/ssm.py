"""Mamba2 (SSD — state-space duality) block in pure JAX.

Chunked SSD for train/prefill (quadratic within chunks + linear state
recurrence across chunks) and an O(1)-per-token recurrent step for decode.
Follows Dao & Gu 2024 (arXiv:2405.21060): scalar A per head, grouped B/C
(n_groups=1), depthwise causal conv over (x, B, C), gated RMSNorm output.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.context import shard_act
from .layers import rmsnorm

Params = dict[str, Any]


def ssm_dims(d_model: int, expand: int, head_dim: int, state: int):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * state  # x plus B, C (n_groups = 1)
    return d_inner, n_heads, conv_dim


def init_ssm(key, d_model: int, *, expand: int, head_dim: int, state: int,
             conv_width: int, dtype) -> Params:
    d_inner, n_heads, conv_dim = ssm_dims(d_model, expand, head_dim, state)
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(d_model)
    in_dim = 2 * d_inner + 2 * state + n_heads  # z, x, B, C, dt
    p = {
        "in_proj": (jax.random.normal(ks[0], (d_model, in_dim)) * sc).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_width, conv_dim))
                   * (1.0 / math.sqrt(conv_width))).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": (jax.random.normal(ks[2], (d_inner, d_model))
                     * (1.0 / math.sqrt(d_inner))).astype(dtype),
    }
    return p


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 init_state: jnp.ndarray | None = None,
                 valid_len: jnp.ndarray | None = None):
    """Depthwise causal conv over the sequence axis.

    x: [B, S, C]; w: [W, C]. Returns (out [B, S, C], tail [B, W-1, C]) where
    `tail` is the conv state to carry into decode. When `valid_len` [B] is
    given (chunked serving prefill with right-padded rows), the tail is
    taken at each row's true end instead of the padded end, so carried
    state matches an unpadded run exactly.
    """
    width = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = init_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1]] * w[i]
    if width > 1:
        if valid_len is None:
            tail = xp[:, -(width - 1):]
        else:
            # window ending at valid_len: xp rows [valid_len, valid_len+W-2]
            idx = valid_len[:, None] + jnp.arange(width - 1)[None, :]
            tail = jnp.take_along_axis(xp, idx[..., None], axis=1)
    else:
        tail = xp[:, :0]
    return out + b, tail


def _segsum_decay(da_cs: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular decay matrix exp(da_cs[q] − da_cs[k]) for k ≤ q.
    da_cs: [..., Q, H] → [..., H, Q, Q]."""
    q = da_cs.shape[-2]
    diff = da_cs[..., :, None, :] - da_cs[..., None, :, :]   # [.., Q, Q, H]
    mask = jnp.tril(jnp.ones((q, q), bool))
    diff = jnp.where(mask[..., None], diff, -jnp.inf)
    return jnp.exp(jnp.moveaxis(diff, -1, -3))                # [.., H, Q, Q]


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                bmat: jnp.ndarray, cmat: jnp.ndarray, chunk: int,
                init_state: jnp.ndarray | None = None):
    """SSD scan. x: [B, S, H, P], dt: [B, S, H] (post-softplus), a: [H] (<0),
    bmat/cmat: [B, S, N]. Returns (y [B, S, H, P], final_state [B, H, N, P]).
    """
    b, s, h, p_dim = x.shape
    n = bmat.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    xc = x.reshape(b, nc, chunk, h, p_dim).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    bc = bmat.reshape(b, nc, chunk, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, chunk, n).astype(jnp.float32)

    da = dtc * a                                   # [b, nc, q, h]
    da_cs = jnp.cumsum(da, axis=2)
    da_sum = da_cs[:, :, -1]                       # [b, nc, h]

    # --- intra-chunk (quadratic, MXU-friendly) ---
    decay = _segsum_decay(da_cs)                   # [b, nc, h, q, k]
    scores = jnp.einsum("bcqn,bckn->bcqk", cc, bc)  # [b, nc, q, k]
    m = scores[:, :, None] * decay                 # [b, nc, h, q, k]
    y_intra = jnp.einsum("bchqk,bckh,bckhp->bcqhp", m, dtc, xc)

    # --- chunk-local states ---
    state_decay = jnp.exp(da_sum[:, :, None] - da_cs)          # [b, nc, q, h]
    sloc = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", bc, dtc * state_decay, xc)

    # --- inter-chunk recurrence ---
    if init_state is None:
        h0 = jnp.zeros((b, h, n, p_dim), jnp.float32)
    else:
        h0 = init_state.astype(jnp.float32)

    chunk_gain = jnp.exp(da_sum)                   # [b, nc, h]

    def step(carry, inp):
        s_c, g_c = inp                             # [b,h,n,p], [b,h]
        prev = carry
        new = prev * g_c[:, :, None, None] + s_c
        return new, prev                           # emit state ENTERING chunk

    final_state, h_prev = jax.lax.scan(
        step, h0, (jnp.moveaxis(sloc, 1, 0), jnp.moveaxis(chunk_gain, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)            # [b, nc, h, n, p]

    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", cc, jnp.exp(da_cs), h_prev)

    y = (y_intra + y_inter).reshape(b, sp, h, p_dim)[:, :s]
    return y, final_state


def ssm_block(x: jnp.ndarray, p: Params, *, head_dim: int, state: int,
              chunk: int, cache: Params | None = None,
              cache_index=None, register_index=None, valid_len=None,
              act_in=None, out_proj_fn=None):
    """Full Mamba2 block. Returns (out [B, S, d], new_cache).

    Two cache layouts: the native per-batch cache ({"conv": [B, W-1, C],
    "state": [B, H, N, P]}), or — when `register_index` [B] is given —
    engine-owned register slot pools ([n_slots, ...] leaves) that are
    gathered by slot on entry and scattered back once at the end, so the
    returned cache is the updated *pool*. `valid_len` [B] masks
    right-padded chunk tails out of the recurrence (decay 1, update 0) so
    carried state after a padded serving chunk equals the unpadded run.

    `act_in(x, tag)` / `out_proj_fn(y, w)` are the PTQ hooks (capture or
    quantize the in/out projection inputs; out_proj is the online-rotation
    site for SSM archs — see DESIGN.md §Arch-applicability)."""
    b, s, d = x.shape
    d_inner = p["out_proj"].shape[0]
    n_heads = p["A_log"].shape[0]

    paged = register_index is not None and cache is not None
    if paged:
        conv_pool, state_pool = cache["conv"], cache["state"]
        cache = {"conv": conv_pool[register_index],
                 "state": state_pool[register_index]}

    if act_in is not None:
        x = act_in(x, "ssm_in")
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * state], axis=-1)

    decode = cache is not None and s == 1
    conv_state_in = cache["conv"] if cache is not None else None
    if decode:
        # roll the conv window by one step
        window = jnp.concatenate([conv_state_in.astype(xbc.dtype), xbc], 1)
        conv_out = jnp.sum(window * p["conv_w"][None], axis=1, keepdims=True) \
            + p["conv_b"]
        new_conv = window[:, 1:]
    else:
        conv_out, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                          init_state=conv_state_in,
                                          valid_len=valid_len)
    xbc = jax.nn.silu(conv_out)

    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + state], axis=-1)
    xs = xs.reshape(b, s, n_heads, head_dim)
    xs = shard_act(xs, ("batch", "seq", "ssm_heads", None))
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if valid_len is not None and not decode:
        # padded tail contributes decay exp(0)=1 and zero update, so the
        # carried SSD state after `valid_len` tokens is exact
        live = jnp.arange(s)[None, :] < valid_len[:, None]
        dtv = jnp.where(live[..., None], dtv, 0.0)
    a = -jnp.exp(p["A_log"])

    if decode:
        prev = cache["state"]                                   # [b,h,n,p]
        da = jnp.exp(dtv[:, 0] * a)                             # [b,h]
        upd = jnp.einsum("bn,bh,bhp->bhnp", bmat[:, 0].astype(jnp.float32),
                         dtv[:, 0], xs[:, 0].astype(jnp.float32))
        new_state = prev * da[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0].astype(jnp.float32),
                       new_state)[:, None]                       # [b,1,h,p]
    else:
        init = cache["state"] if cache is not None else None
        y, new_state = ssd_chunked(xs, dtv, a, bmat, cmat, chunk,
                                   init_state=init)

    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    # gated RMSNorm (Mamba2: norm(y * silu(z)))
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    if out_proj_fn is not None:
        out = out_proj_fn(y, p["out_proj"])
    else:
        out = y @ p["out_proj"]
    out = shard_act(out, ("batch", "seq", "embed"))

    new_cache = None
    if cache is not None:
        if paged:
            # scatter updated per-row state back to its register slot;
            # padded rows target the scratch slot (harmless dead writes)
            new_cache = {
                "conv": conv_pool.at[register_index].set(
                    new_conv.astype(conv_pool.dtype)),
                "state": state_pool.at[register_index].set(new_state),
            }
        else:
            new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                         "state": new_state}
    return out, new_cache


def init_ssm_cache(batch: int, d_model: int, *, expand: int, head_dim: int,
                   state: int, conv_width: int, dtype=jnp.bfloat16) -> Params:
    d_inner, n_heads, conv_dim = ssm_dims(d_model, expand, head_dim, state)
    return {
        "conv": jnp.zeros((batch, conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, n_heads, state, head_dim), jnp.float32),
    }
