"""Shared neural-net layers: norms, RoPE, GQA attention (+KV cache, chunked
flash-style long-context path), SwiGLU/GELU feed-forward.

Everything is a pure function over explicit param dicts (stacked-over-layers
leaves scan cleanly, and the PTQ pipeline can walk/merge weights directly).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.context import mesh_axis_size, shard_act
from repro.kernels import ops as kops

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
              eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)
            + beta.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, p: Params, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """RoPE inverse frequencies — the model-side twin of
    `kernels.paged_attention.rope_frequencies`.

    The two CANNOT be one function: the kernel needs a host-side numpy
    literal (a trace-invariant constant, or its operand and the
    reference's embedded constant would round `pow` differently and break
    the kernel-vs-reference bit-for-bit contract), while the model's
    traced computation constant-folds through XLA, whose `pow` rounds up
    to 2 ulp away from numpy's. Swapping the model onto the numpy literal
    shifts every rotation by those ulps — enough to flip activation-quant
    rounding ties downstream. `tests/test_kernels.py::
    test_rope_frequency_literals_agree` pins the twins together (≤ 2 ulp
    elementwise over the config sweep) so they cannot silently drift.
    """
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: [B, S, H, Dh]; positions: [B, S] (absolute)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                      # [Dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [B, S, Dh/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    causal: bool = True
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    chunk_q: int = 2048    # flash-style chunking thresholds
    chunk_kv: int = 2048


def init_attention(key, d_model: int, spec: AttnSpec, dtype) -> Params:
    ks = jax.random.split(key, 4)
    h, kv, dh = spec.n_heads, spec.n_kv_heads, spec.head_dim
    sc = 1.0 / math.sqrt(d_model)
    p = {
        "wq": (jax.random.normal(ks[0], (d_model, h * dh)) * sc).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, kv * dh)) * sc).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, kv * dh)) * sc).astype(dtype),
        "wo": (jax.random.normal(ks[3], (h * dh, d_model))
               * (1.0 / math.sqrt(h * dh))).astype(dtype),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((kv * dh,), dtype)
        p["bv"] = jnp.zeros((kv * dh,), dtype)
    return p


def _dense_attention(q, k, v, *, causal, q_offset=0):
    """Reference attention: q [B,Sq,H,Dh], k/v [B,Sk,KH,Dh] (H = KH·G)."""
    b, sq, h, dh = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, sq, kh, g, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(dh)
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = kpos[None, :] <= qpos[:, None]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def _chunked_attention(q, k, v, *, causal, chunk_q, chunk_kv, q_offset=0):
    """Flash-style online-softmax attention scanning KV chunks.

    Compiled memory is O(chunk_q · chunk_kv) per head instead of O(S²) —
    required for the 32k-prefill and 500k cells to fit HBM.
    """
    b, sq, h, dh = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    nq = -(-sq // chunk_q)
    pad_q = nq * chunk_q - sq
    nk = -(-sk // chunk_kv)
    pad_k = nk * chunk_kv - sk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v

    qp = qp.reshape(b, nq, chunk_q, kh, g, dh).astype(jnp.float32)
    kp = kp.reshape(b, nk, chunk_kv, kh, dh).astype(jnp.float32)
    vp = vp.reshape(b, nk, chunk_kv, kh, dh).astype(jnp.float32)
    scale = 1.0 / math.sqrt(dh)

    def per_qchunk(qi, qc):
        # qc: [b, chunk_q, kh, g, dh]
        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, kc, vc = inputs
            logits = jnp.einsum("bqkgd,bskd->bkgqs", qc, kc) * scale
            qpos = qi * chunk_q + jnp.arange(chunk_q) + q_offset
            kpos = ki * chunk_kv + jnp.arange(chunk_kv)
            valid = kpos[None, :] < sk
            if causal:
                valid = valid & (kpos[None, :] <= qpos[:, None])
            logits = jnp.where(valid[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vc)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, chunk_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kh, g, chunk_q), jnp.float32)
        a0 = jnp.zeros((b, kh, g, chunk_q, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kp, 1, 0), jnp.moveaxis(vp, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.einsum("bkgqd->bqkgd", out)

    outs = jax.lax.map(lambda args: per_qchunk(*args),
                       (jnp.arange(nq), jnp.moveaxis(qp, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * chunk_q, h, dh)
    if pad_q:
        out = out[:, :sq]
    return out.astype(q.dtype)


def paged_write_rows(buf: jnp.ndarray, val: jnp.ndarray,
                     block_table: jnp.ndarray,
                     positions: jnp.ndarray) -> jnp.ndarray:
    """Scatter new KV rows straight into their pages.

    buf [n_pages, T, ...] pool leaf; val [B, S, ...]; block_table [B, P]
    int32; positions [B, S] absolute positions. Row (b, s) lands at
    (block_table[b, positions//T], positions % T). Padded rows target the
    scratch page (or not-yet-valid in-page slots that are overwritten
    before the causal mask ever exposes them), so duplicate writes are
    harmless.
    """
    t = buf.shape[1]
    page_ids = jnp.take_along_axis(block_table, positions // t, axis=1)
    return buf.at[page_ids, positions % t].set(val.astype(buf.dtype))


def attention(x: jnp.ndarray, p: Params, spec: AttnSpec, *,
              positions: jnp.ndarray | None = None,
              cache: Params | None = None,
              cache_index: jnp.ndarray | None = None,
              block_table: jnp.ndarray | None = None,
              seq_lengths: jnp.ndarray | None = None,
              act_in=None):
    """GQA attention. Returns (out, new_cache).

    cache = {"k": [B, S_max, KH, Dh], "v": ...} for decode; `cache_index`
    is the current fill position (scalar int32, or [B] per-slot vector).
    With `block_table` [B, P] the cache is instead a *paged view* — leaves
    [n_pages, page_size, KH, Dh] — and attention is block-table-native:
    the new rows are scattered straight into their pages and the kernel
    walks the table (`kernels.ops.paged_attention`), no gathered slab.
    `seq_lengths` [B] (paged path only) are the true per-sequence context
    lengths the scheduler dispatches — the kernel's ragged early-exit
    skips every page column past ceil(len/page_size); without them the
    kernel derives the bound from the query positions. `act_in(x, tag)`
    is the PTQ hook applied to every projection input (quantize or
    capture).
    """
    b, s, d = x.shape
    h, kv, dh = spec.n_heads, spec.n_kv_heads, spec.head_dim
    if act_in is not None:
        x = act_in(x, "qkv")
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if spec.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, kv, dh)
    v = v.reshape(b, s, kv, dh)
    q = shard_act(q, ("batch", "seq", "heads", "head_dim"))
    k = shard_act(k, ("batch", "seq", "kv_heads", "head_dim"))

    # cache_index may be a scalar (lockstep batch) or [B] (per-slot fill
    # positions, as used by the continuous-batching scheduler).
    per_slot = cache_index is not None and jnp.ndim(cache_index) == 1

    if positions is None:
        if cache_index is None:
            base = jnp.zeros((b, 1), jnp.int32)
        elif per_slot:
            base = cache_index[:, None]
        else:
            base = jnp.broadcast_to(cache_index, (b,))[:, None]
        positions = jnp.arange(s)[None, :] + base
    q = apply_rope(q, positions, spec.rope_theta)
    k = apply_rope(k, positions, spec.rope_theta)

    new_cache = None
    if cache is not None and block_table is not None:
        # block-table-native path: write the new rows straight into their
        # pages, then attend by walking the table — no gathered slab. K is
        # stored post-RoPE, so the kernel applies no rotation.
        new_cache = {
            "k": paged_write_rows(cache["k"], k, block_table, positions),
            "v": paged_write_rows(cache["v"], v, block_table, positions),
        }
        out = kops.paged_attention(q, new_cache, block_table, positions,
                                   seq_lengths).astype(x.dtype)
    elif cache is not None:
        if per_slot:
            if s != 1:
                raise ValueError("per-slot cache_index requires q_len == 1")
            rows = jnp.arange(b)
            ck = cache["k"].at[rows, cache_index].set(
                k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows, cache_index].set(
                v[:, 0].astype(cache["v"].dtype))
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k_all, v_all = ck, cv
        s_k = ck.shape[1]
        if s == 1:
            # decode: mask positions beyond the (per-row) fill point
            msize = mesh_axis_size("model")
            seq_sharded_cache = (msize > 1 and kv % msize != 0
                                 and s_k % msize == 0)
            cache_axes = ("batch", "kv_cache_seq", None, None) \
                if seq_sharded_cache else \
                ("batch", None, "kv_heads", None)
            k_all = shard_act(k_all, cache_axes)
            v_all = shard_act(v_all, cache_axes)
            kpos = jnp.arange(s_k)
            if per_slot:
                valid = kpos[None, :] <= cache_index[:, None]   # [B, S_k]
            else:
                valid = jnp.broadcast_to(kpos <= cache_index, (b, s_k))
            g = h // kv
            qg = q.reshape(b, 1, kv, g, dh)
            logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                                k_all.astype(jnp.float32)) / math.sqrt(dh)
            logits = jnp.where(valid[:, None, None, None, :], logits, -1e30)
            if seq_sharded_cache:
                # keep the kv_len axis sharded through the softmax so SPMD
                # emits partial-softmax all-reduces (flash-decoding) instead
                # of gathering the whole cache
                logits = shard_act(
                    logits, ("batch", None, None, None, "kv_cache_seq"))
            probs = jax.nn.softmax(logits, axis=-1)
            out = jnp.einsum("bkgqs,bskd->bqkgd", probs,
                             v_all.astype(jnp.float32))
            out = out.reshape(b, 1, h, dh).astype(x.dtype)
        elif s <= spec.chunk_q and s_k <= spec.chunk_kv:
            # short chunked-prefill block (the serving engine's common
            # case): the flash path would pad q/kv to the 2048-wide chunk
            # tiles, turning a 4-token chunk into a 2048² attention — the
            # dense path with a query offset is exact and ~chunk²/s·s_k
            # cheaper. Stale cache rows beyond the fill point sit at
            # positions > every query position, so the causal mask hides
            # them just as the flash path's validity mask does.
            out = _dense_attention(q, k_all, v_all, causal=spec.causal,
                                   q_offset=cache_index)
        else:
            out = _chunked_attention(q, k_all, v_all, causal=spec.causal,
                                     chunk_q=spec.chunk_q,
                                     chunk_kv=spec.chunk_kv,
                                     q_offset=cache_index)
    else:
        if s > spec.chunk_q:
            out = _chunked_attention(q, k, v, causal=spec.causal,
                                     chunk_q=spec.chunk_q,
                                     chunk_kv=spec.chunk_kv)
        else:
            out = _dense_attention(q, k, v, causal=spec.causal)

    out = out.reshape(b, s, h * dh)
    if act_in is not None:
        out = act_in(out, "wo")
    out = out @ p["wo"]
    return shard_act(out, ("batch", "seq", "embed")), new_cache


def init_attention_cache(batch: int, max_len: int, spec: AttnSpec,
                         dtype=jnp.bfloat16) -> Params:
    kv, dh = spec.n_kv_heads, spec.head_dim
    return {"k": jnp.zeros((batch, max_len, kv, dh), dtype),
            "v": jnp.zeros((batch, max_len, kv, dh), dtype)}


# ---------------------------------------------------------------------------
# Feed-forward
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, act: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    sc_in = 1.0 / math.sqrt(d_model)
    sc_out = 1.0 / math.sqrt(d_ff)
    p = {
        "w_up": (jax.random.normal(ks[1], (d_model, d_ff)) * sc_in).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (d_ff, d_model)) * sc_out).astype(dtype),
    }
    if act == "silu":  # SwiGLU
        p["w_gate"] = (jax.random.normal(ks[0], (d_model, d_ff)) * sc_in).astype(dtype)
    return p


def mlp(x: jnp.ndarray, p: Params, act: str,
        down_proj_fn=None, act_in=None) -> jnp.ndarray:
    """SwiGLU (act="silu") or plain GELU MLP. `down_proj_fn(h, w_down)`
    overrides the final projection — the PTQ hook where the online block
    rotation + quantized GEMM is injected (Figure 7, R̃₃)."""
    if act_in is not None:
        x = act_in(x, "ffn")
    if act == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    h = shard_act(h, ("batch", "seq", "mlp"))
    if down_proj_fn is not None:
        out = down_proj_fn(h, p["w_down"])
    else:
        out = h @ p["w_down"]
    return shard_act(out, ("batch", "seq", "embed"))


def init_norm(d_model: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d_model,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d_model,), dtype)
    return p
