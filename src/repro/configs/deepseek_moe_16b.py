"""DeepSeekMoE 16B [arXiv:2401.06066]: 2 shared + 64 routed fine-grained
experts, top-6, expert d_ff=1408.

Simplification (documented): the real model's dense first layer is modeled
as MoE like the rest — layer-heterogeneity is orthogonal to both the PTQ
technique and the distribution schema.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, vocab=102_400,
    n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=0, act="silu", norm="rmsnorm",
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
    capacity_factor=1.25,
)
