"""HuBERT X-Large audio encoder backbone [arXiv:2106.07447].

Encoder-only (bidirectional), GELU MLP, LayerNorm. The conv waveform stem is
a stub: `input_specs` supplies precomputed 512-dim frame features which are
projected to d_model. vocab=504 is the masked-prediction codebook.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, vocab=504,
    n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, act="gelu", norm="layernorm",
    causal=False, rope_theta=10_000.0,
    frontend="audio_frames",
    notes="encoder-only: decode shapes skipped",
)
