"""Granite-3.0 2B dense decoder [hf:ibm-granite/granite-3.0-2b-base]: GQA kv=8."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, vocab=49_155,
    n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, act="silu", norm="rmsnorm",
)
