"""Llama-4 Maverick 400B-A17B MoE [hf:meta-llama/Llama-4]: 128 routed experts
top-1 + 1 shared expert, early fusion.

Simplification (documented): all 48 layers are MoE (the real model
interleaves dense layers); ZeRO-3 weight sharding is required to fit HBM.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, vocab=202_048,
    n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=0, act="silu", norm="rmsnorm",
    n_experts=128, n_shared_experts=1, top_k=1, moe_d_ff=8192,
    capacity_factor=1.25,
)
