"""Mamba2-1.3B pure SSM (SSD) [arXiv:2405.21060]. Attention-free."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, vocab=50_280,
    d_ff=0,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    notes="attention-free; sub-quadratic: runs long_500k. PeRQ applies to "
          "the in-proj gate region with head-preserving permutations "
          "(DESIGN.md §Arch-applicability).",
)
