"""Zamba2-1.2B hybrid: Mamba2 backbone + shared attention block
[arXiv:2411.15242].

38 Mamba2 layers; one parameter-shared attention+FFN block is applied after
every 6 SSM layers (6 invocations; the 2 trailing layers run without a
shared-block call). ssm_state=64 per the assignment.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, vocab=32_000,
    n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, act="silu", norm="rmsnorm",
    ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    hybrid_period=6,
    notes="sub-quadratic: runs long_500k",
)
