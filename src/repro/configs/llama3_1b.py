"""Llama-3.2 1B [arXiv:2407.21783] — the paper's own evaluation family.
Used by the PTQ benchmark harnesses (Table 1/2 surrogates) and examples."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama3-1b", family="dense",
    n_layers=16, d_model=2048, vocab=128_256,
    n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, act="silu", norm="rmsnorm",
    rope_theta=500_000.0,
)
