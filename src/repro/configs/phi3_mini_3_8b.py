"""Phi-3-mini 3.8B dense decoder [arXiv:2404.14219]: RoPE/SwiGLU/GQA."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, vocab=32_064,
    n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, act="silu", norm="rmsnorm",
)
