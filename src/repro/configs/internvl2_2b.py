"""InternVL2-2B VLM backbone [arXiv:2404.16821]: InternLM2-1.8B LM with an
InternViT frontend stub (precomputed 1024-dim patch embeddings, 256 patches
prepended — early fusion)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, vocab=92_553,
    n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, act="silu", norm="rmsnorm",
    frontend="vision_patches", frontend_tokens=256,
)
