"""DeepSeek-Coder 33B dense decoder [arXiv:2401.14196]: llama-arch, GQA kv=8.

d_ff = 19200 exercises the non-power-of-2 full-vector Hadamard
(19200 = 2^6·300, Paley-II base H_300).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, vocab=32_256,
    n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=19_200, act="silu", norm="rmsnorm",
)
