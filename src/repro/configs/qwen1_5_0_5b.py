"""Qwen1.5-0.5B dense decoder [hf:Qwen/Qwen1.5-0.5B]: QKV bias, huge vocab."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, vocab=151_936,
    n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=2816, act="silu", norm="rmsnorm",
    qkv_bias=True,
)
