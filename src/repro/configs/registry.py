"""Architecture registry: maps --arch ids to config modules."""
from __future__ import annotations

import importlib

from repro.models.config import ArchConfig

ARCH_IDS = [
    "hubert-xlarge",
    "zamba2-1.2b",
    "mamba2-1.3b",
    "phi3-mini-3.8b",
    "granite-3-2b",
    "deepseek-coder-33b",
    "qwen1.5-0.5b",
    "deepseek-moe-16b",
    "llama4-maverick-400b-a17b",
    "internvl2-2b",
    # the paper's own model family (used by the PTQ benchmarks/examples)
    "llama3-1b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG.validate()


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
