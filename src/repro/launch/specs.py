"""ShapeDtypeStruct stand-ins for every model input — the dry-run contract.

`input_specs(cfg, cell)` returns abstract inputs for the step that the cell
lowers (train_step / prefill_step / decode_step), weak-type-correct and
shardable, with zero device allocation. Microbatching factors are chosen
here so the compiled per-device memory fits v5e HBM (16 GiB).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, ShapeCell
from repro.models.transformer import FRONTEND_DIMS, Model

Abstract = Any


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ArchConfig, batch: int, seq: int, *,
                with_labels: bool) -> dict:
    out: dict[str, Abstract] = {}
    if cfg.frontend == "audio_frames":
        out["frames"] = sds((batch, seq, FRONTEND_DIMS["audio_frames"]),
                            jnp.bfloat16)
        if with_labels:
            out["labels"] = sds((batch, seq), jnp.int32)
        return out
    if cfg.frontend == "vision_patches":
        npatch = cfg.frontend_tokens
        out["patches"] = sds((batch, npatch, FRONTEND_DIMS["vision_patches"]),
                             jnp.bfloat16)
        out["tokens"] = sds((batch, seq - npatch), jnp.int32)
        if with_labels:
            out["labels"] = sds((batch, seq - npatch), jnp.int32)
        return out
    out["tokens"] = sds((batch, seq), jnp.int32)
    if with_labels:
        out["labels"] = sds((batch, seq), jnp.int32)
    return out


def params_specs(model: Model) -> Abstract:
    return model.init_abstract()


def cache_specs(model: Model, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> Abstract:
    return jax.eval_shape(
        lambda: model.init_cache(batch, max_len, dtype=dtype))


@dataclasses.dataclass(frozen=True)
class CellPlan:
    """Everything the dry-run needs to lower one (arch × shape) cell."""
    arch: str
    cell: ShapeCell
    kind: str                    # train | prefill | decode
    num_microbatches: int = 1    # train only

    @property
    def name(self) -> str:
        return f"{self.arch}/{self.cell.name}"


# Per-arch microbatch factors for train_4k (global batch 256). Chosen so the
# per-device live activation set fits 16 GiB HBM together with params+opt:
# larger models → more microbatches.
TRAIN_MICROBATCHES = {
    "deepseek-coder-33b": 16,
    # 8 (not 16): ZeRO-3 weight gathers scale with the microbatch count;
    # §Perf cell A measured 16→8 as a 1.7× collective-time reduction at
    # +2 GiB/device of activations.
    "llama4-maverick-400b-a17b": 8,
    "phi3-mini-3.8b": 8,
    "deepseek-moe-16b": 8,
    "hubert-xlarge": 8,
    "zamba2-1.2b": 8,
    "mamba2-1.3b": 8,
}
DEFAULT_TRAIN_MICROBATCHES = 4


def plan_for(cfg: ArchConfig, cell: ShapeCell) -> CellPlan:
    n_micro = TRAIN_MICROBATCHES.get(cfg.name, DEFAULT_TRAIN_MICROBATCHES) \
        if cell.kind == "train" else 1
    return CellPlan(arch=cfg.name, cell=cell, kind=cell.kind,
                    num_microbatches=n_micro)


def input_specs(model: Model, plan: CellPlan) -> dict:
    """Abstract inputs for the step function this cell lowers."""
    cfg = model.cfg
    cell = plan.cell
    if plan.kind == "train":
        return {
            "batch": batch_specs(cfg, cell.global_batch, cell.seq_len,
                                 with_labels=True),
        }
    if plan.kind == "prefill":
        if cfg.family == "encoder":
            # encoder "prefill" = full forward, no cache
            return {"batch": batch_specs(cfg, cell.global_batch,
                                         cell.seq_len, with_labels=False)}
        return {
            "batch": batch_specs(cfg, cell.global_batch, cell.seq_len,
                                 with_labels=False),
            "cache": cache_specs(model, cell.global_batch, cell.seq_len),
        }
    if plan.kind == "decode":
        return {
            "tokens": sds((cell.global_batch, 1), jnp.int32),
            "cache": cache_specs(model, cell.global_batch, cell.seq_len),
            "index": sds((), jnp.int32),
        }
    raise ValueError(plan.kind)
