"""Serving launcher CLI: quantize (PeRQ) then serve with continuous
batching.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \\
        --reduced --preset perq_star --block-size 16 --requests 8

`--integer-path` swaps in the packed-int4 integer execution engine
(`repro.serve.quantized`, dense archs) with an optional int4/int8 KV cache.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.core import pipeline as PL
from repro.core.synthetic import inject_outlier_channels
from repro.models.transformer import build_model
from repro.serve.step import BatchScheduler, Request, make_decode_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--preset", default="perq_star",
                    choices=sorted(PL.PRESETS))
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--integer-path", action="store_true")
    ap.add_argument("--kv-bits", type=int, default=None, choices=[4, 8])
    ap.add_argument("--no-quant", action="store_true",
                    help="serve the bf16 model instead")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = inject_outlier_channels(model.init(jax.random.PRNGKey(0)))

    if args.no_quant:
        smodel, sparams = model, params
    else:
        key = jax.random.PRNGKey(1)
        calib = [{"tokens": jax.random.randint(key, (4, 128), 0, cfg.vocab),
                  "labels": jnp.zeros((4, 128), jnp.int32)}]
        res = PL.quantize_model(
            model, params, calib,
            PL.preset(args.preset, block_size=args.block_size,
                      cayley_steps=8))
        smodel, sparams = PL.build_quantized_model(model, res), res.params
        print(f"quantized with {args.preset} (b={args.block_size})")

    rng = np.random.default_rng(0)
    if args.integer_path:
        from repro.serve.quantized import QuantizedDenseLM, \
            pack_dense_params
        qlm = QuantizedDenseLM(cfg, block_size=args.block_size,
                               kv_bits=args.kv_bits)
        packed = pack_dense_params(sparams, cfg)
        dec = jax.jit(lambda p, t, c, i: qlm.decode_step(p, t, c, i))
        cache = qlm.init_cache(1, args.max_len)
        prompt = rng.integers(0, cfg.vocab, size=6).tolist()
        toks, nxt = [], None
        for i, t in enumerate(prompt):
            logits, cache = dec(packed, jnp.asarray([[t]], jnp.int32),
                                cache, jnp.asarray(i, jnp.int32))
            nxt = int(jnp.argmax(logits[0]))
        for j in range(args.max_new):
            toks.append(nxt)
            logits, cache = dec(packed, jnp.asarray([[nxt]], jnp.int32),
                                cache, jnp.asarray(len(prompt) + j,
                                                   jnp.int32))
            nxt = int(jnp.argmax(logits[0]))
        print(f"integer path (kv_bits={args.kv_bits}): "
              f"prompt {prompt} → {toks}")
        return

    sched = BatchScheduler(smodel, sparams, slots=args.slots,
                           max_len=args.max_len)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              size=int(rng.integers(3, 9))).tolist()
        sched.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))
    done = sched.run()
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: {r.prompt} → {r.generated}")


if __name__ == "__main__":
    main()
