"""Serving launcher CLI: quantize (PeRQ) then serve through the paged-KV
continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \\
        --reduced --preset perq_star --block-size 16 --requests 8

Every path runs batched through `repro.serve.engine.ServeEngine` (paged
state pools, chunked prefill, per-step admission): the bf16 model
(`--no-quant`), the fake-quant PTQ output (default), and the packed-int4
integer engine (`--integer-path`, dense archs, optional `--kv-bits {4,8}`
integer KV pages). The engine serves every decode-capable token-LM family
in the registry — dense, MoE, pure-SSM, hybrid — through the same
scheduler (`--model mamba2-1.3b --reduced --no-quant` serves the Mamba2
smoke config); encoder/frontend archs are rejected with a capability
error. `--legacy-scheduler` keeps the old dense-slot `BatchScheduler` for
comparison (bf16/fake-quant only).

`--prefix-cache` turns on the prefix-sharing radix cache (refcounted
copy-on-write KV pages, kv-only specs; `--prefix-cache-pages N` bounds
the LRU tree), and `--shared-prefix N` prepends one N-token system
prompt to every request to exercise it; the run summary then reports the
prefix hit-rate.

`--swap-host-mb MB` attaches a host KV swap tier (`--swap-policy
{never,cost,always}` picks when swap beats recompute-by-replay under
page pressure), and `--drain-after N` exercises graceful shutdown:
after N steps admission stops and the engine drains every tier empty.

Observability: `--metrics-json PATH` writes the engine's schema-validated
registry snapshot, `--trace PATH` records request lifecycles and fused
dispatches as Chrome Trace JSON (open in https://ui.perfetto.dev), and
`--probe-every K` samples the rotation-quality activation probes on the
integer path. Every engine run ends with a one-line summary (tokens/s,
per-token latency quantiles, peak pool utilization, admission wait)
computed from the same registry snapshot.
"""
import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.core import pipeline as PL
from repro.core.synthetic import inject_outlier_channels
from repro.models.transformer import build_model
from repro.serve.engine import (EngineRequest, SamplingParams, ServeEngine,
                                as_servable, pages_for)
from repro.serve.step import BatchScheduler, Request
from repro.serve.telemetry import (QualityProbes, Tracer, validate_snapshot,
                                   validate_trace)


def _ms(v) -> str:
    return "n/a" if v is None else f"{v * 1e3:.1f}ms"


def summary_line(snap: dict) -> str:
    """One-line end-of-run summary from a registry snapshot."""
    c, g, h = snap["counters"], snap["gauges"], snap["histograms"]
    toks = c["engine.generated_tokens"]
    wall = h["engine.step.wall_s"]["sum"]
    lat = h["engine.decode.token_latency_s"]
    wait = h["engine.admission.wait_s"]
    out = (f"summary: {toks} tokens in {wall:.2f}s engine time "
           f"({toks / max(wall, 1e-9):.1f} tok/s) | "
           f"token latency p50 {_ms(lat['p50'])} p95 {_ms(lat['p95'])} | "
           f"peak pages {g['engine.pages.peak_in_use']:.0f}"
           f"/{g['engine.pages.capacity']:.0f} "
           f"({g['engine.pages.utilization_peak']:.0%} peak util)")
    if "engine.register_slots.peak_in_use" in g:
        out += (f" | peak slots {g['engine.register_slots.peak_in_use']:.0f}"
                f"/{g['engine.register_slots.capacity']:.0f}")
    lookups = c["engine.prefix.hits"] + c["engine.prefix.misses"]
    if lookups:
        out += (f" | prefix hit-rate "
                f"{c['engine.prefix.hits'] / lookups:.0%} "
                f"({c['engine.prefix.hit_tokens']} tokens, "
                f"{c['engine.prefix.cow_copies']} COW)")
    if c["engine.swap.out"] or c["engine.swap.in"] or c["engine.swap.fallbacks"]:
        out += (f" | swap out {c['engine.swap.out']} "
                f"in {c['engine.swap.in']} "
                f"({c['engine.swap.bytes'] / 2**20:.1f} MiB, "
                f"{c['engine.swap.retries']} retries, "
                f"{c['engine.swap.fallbacks']} fallbacks)")
    out += (f" | preempt {c['engine.preemptions']} "
            f"cancel {c['engine.requests.cancelled']} "
            f"expire {c['engine.requests.expired']} "
            f"fail {c['engine.requests.failed']}")
    return out + f" | admission wait p95 {_ms(wait['p95'])}"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", "--model", dest="arch",
                    default="qwen1.5-0.5b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--preset", default="perq_star",
                    choices=sorted(PL.PRESETS))
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k most likely tokens "
                    "(0 disables)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 disables)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--integer-path", action="store_true")
    ap.add_argument("--kv-bits", type=int, default=None, choices=[4, 8])
    ap.add_argument("--no-quant", action="store_true",
                    help="serve the bf16 model instead")
    ap.add_argument("--legacy-scheduler", action="store_true",
                    help="use the dense-slot BatchScheduler (no paging)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the schema-validated engine metrics "
                    "snapshot as JSON")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a Chrome Trace Event JSON (Perfetto)")
    ap.add_argument("--probe-every", type=int, default=0, metavar="K",
                    help="sample rotation-quality activation probes every "
                    "K decode dispatches (integer path only; 0 disables)")
    ap.add_argument("--admission", default="optimistic",
                    choices=["optimistic", "reserve"],
                    help="admission policy: optimistic (prompt pages + "
                    "headroom, preemption-backed) or reserve (worst-case "
                    "pages up front, never preempts)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request TTL in seconds, enforced at step "
                    "boundaries (expired requests return their pages)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable the prefix-sharing radix cache "
                    "(refcounted copy-on-write KV pages; kv-only specs)")
    ap.add_argument("--prefix-cache-pages", type=int, default=None,
                    metavar="N", help="LRU budget of pool pages the radix "
                    "tree may hold (default: unbounded — pressure evicts)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend the same N-token system prompt to every "
                    "request (exercises the prefix cache)")
    ap.add_argument("--swap-host-mb", type=float, default=None, metavar="MB",
                    help="attach a host KV swap tier of this many MiB: "
                    "under page pressure the engine may swap a victim's "
                    "pages to host instead of preempting it for recompute")
    ap.add_argument("--swap-policy", default="cost",
                    choices=["never", "cost", "always"],
                    help="when to prefer swap over recompute-by-replay "
                    "under pressure: cost-model the round-trip bytes vs "
                    "replayed tokens (default), always swap, or never "
                    "(preempt only; implied without --swap-host-mb)")
    ap.add_argument("--drain-after", type=int, default=None, metavar="N",
                    help="after N engine steps stop admission and drain: "
                    "never-admitted requests cancel, in-flight work "
                    "(including swapped residents) finishes, and the "
                    "engine asserts every tier came back empty")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = inject_outlier_channels(model.init(jax.random.PRNGKey(0)))

    if args.no_quant:
        smodel, sparams = model, params
    else:
        key = jax.random.PRNGKey(1)
        calib = [{"tokens": jax.random.randint(key, (4, 128), 0, cfg.vocab),
                  "labels": jnp.zeros((4, 128), jnp.int32)}]
        res = PL.quantize_model(
            model, params, calib,
            PL.preset(args.preset, block_size=args.block_size,
                      cayley_steps=8))
        smodel, sparams = PL.build_quantized_model(model, res), res.params
        print(f"quantized with {args.preset} (b={args.block_size})")

    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab, size=args.shared_prefix).tolist()
    prompts = [system + rng.integers(0, cfg.vocab,
                                     size=int(rng.integers(3, 9))).tolist()
               for _ in range(args.requests)]

    if args.probe_every and not args.integer_path:
        raise SystemExit("--probe-every needs --integer-path: the probes "
                         "read the fused rotate+quantize site")
    if args.legacy_scheduler:
        if args.integer_path:
            raise SystemExit("--legacy-scheduler cannot drive the integer "
                             "path; the paged engine serves it")
        if args.metrics_json or args.trace:
            raise SystemExit("--metrics-json/--trace instrument the paged "
                             "engine; drop --legacy-scheduler")
        if args.top_k > 0 or args.top_p < 1.0:
            raise SystemExit("--legacy-scheduler has no top-k/top-p "
                             "support; drop the flags or use the engine")
        if args.prefix_cache:
            raise SystemExit("--prefix-cache is a paged-engine feature; "
                             "drop --legacy-scheduler")
        if args.swap_host_mb is not None or args.drain_after is not None:
            raise SystemExit("--swap-host-mb/--drain-after are paged-"
                             "engine features; drop --legacy-scheduler")
        sched = BatchScheduler(smodel, sparams, slots=args.slots,
                               max_len=args.max_len,
                               temperature=args.temperature)
        for rid, prompt in enumerate(prompts):
            sched.submit(Request(rid=rid, prompt=prompt,
                                 max_new=args.max_new))
        done = sched.run()
        for r in sorted(done, key=lambda r: r.rid):
            print(f"req {r.rid}: {r.prompt} → {r.generated}")
        return

    if args.integer_path:
        if cfg.family not in ("dense", "vlm"):
            raise SystemExit(f"--integer-path packs dense projections only; "
                             f"{cfg.name} is family {cfg.family!r}")
        from repro.serve.quantized import QuantizedDenseLM, \
            pack_dense_params
        qlm = QuantizedDenseLM(cfg, block_size=args.block_size,
                               kv_bits=args.kv_bits)
        adapter = as_servable(qlm, pack_dense_params(sparams, cfg))
        label = f"integer path (kv_bits={args.kv_bits})"
    else:
        adapter = as_servable(smodel, sparams,
                              name="bf16" if args.no_quant else "fake-quant")
        label = "bf16" if args.no_quant else "fake-quant"

    # enough pool for every slot to hold its worst-case sequence (the
    # larger of --max-len and the longest prompt + --max-new, which is
    # what engine admission reserves), plus the reserved scratch page
    per_seq = max([pages_for(args.max_len, args.page_size)]
                  + [pages_for(len(p) + args.max_new, args.page_size)
                     for p in prompts])
    n_pages = args.slots * per_seq + 1
    tracer = Tracer() if args.trace else None
    probes = QualityProbes(every_k=args.probe_every) if args.probe_every \
        else None
    engine = ServeEngine(adapter, n_pages=n_pages, page_size=args.page_size,
                         max_seqs=args.slots,
                         prefill_chunk=args.prefill_chunk,
                         admission=args.admission,
                         deadline_s=args.deadline_s,
                         max_context=args.max_len,
                         prefix_cache=args.prefix_cache,
                         prefix_cache_pages=args.prefix_cache_pages,
                         swap_host_mb=args.swap_host_mb,
                         swap_policy=args.swap_policy,
                         tracer=tracer, quality_probes=probes)
    for rid, prompt in enumerate(prompts):
        engine.submit(EngineRequest(
            rid=rid, prompt=prompt,
            sampling=SamplingParams(temperature=args.temperature,
                                    max_new=args.max_new,
                                    top_k=args.top_k, top_p=args.top_p)))
    if args.drain_after is not None:
        done = []
        while (engine.queue or engine.active) \
                and engine.n_steps < args.drain_after:
            done.extend(engine.step())
        done.extend(engine.drain())
    else:
        done = engine.run()
    print(f"{label}: served {len(done)} requests over {args.slots} slots "
          f"in {engine.n_steps} engine steps "
          f"({engine.n_prefill_tokens} prefill + "
          f"{engine.n_decode_tokens} decode tokens)")
    for r in sorted(done, key=lambda r: r.rid):
        mark = "" if r.outcome in ("length", "stop") else f" [{r.outcome}]"
        print(f"req {r.rid}: {r.prompt} → {r.generated}{mark}")

    snap = engine.metrics_snapshot()
    validate_snapshot(snap)     # never write an off-schema artifact
    print(summary_line(snap))
    if probes is not None:
        imb = snap["histograms"]["quality.l1_imbalance_post"]
        print(f"quality: post-rotation l1 imbalance p50 {imb['p50']:.3f} "
              f"over {imb['count']} layer observations "
              f"({snap['counters']['quality.probe_dispatches']} probed "
              "dispatches)")
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(snap, f, indent=1)
        print(f"metrics snapshot → {args.metrics_json}")
    if tracer is not None:
        validate_trace(tracer.to_dict())
        tracer.save(args.trace)
        print(f"trace ({len(tracer.events)} events) → {args.trace}")


if __name__ == "__main__":
    main()
