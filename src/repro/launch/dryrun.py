import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this script:
  1. builds the abstract params / batch / cache (ShapeDtypeStructs — no
     allocation),
  2. jits the right step function (train / prefill / decode) with explicit
     in/out shardings on the production mesh,
  3. `.lower(...)` then `.compile()` — any sharding mismatch, unsupported
     collective, or compile-time OOM fails the cell,
  4. records `memory_analysis()` (proves it fits), `cost_analysis()`
     (FLOPs/bytes for §Roofline) and the per-collective byte counts parsed
     from the optimized HLO text (for the collective roofline term),
  5. writes one JSON per cell to artifacts/dryrun/.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b
    PYTHONPATH=src python -m repro.launch.dryrun --shape train_4k --multi-pod both
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.distributed import shardings as SH
from repro.distributed.context import mesh_context
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import CellPlan, input_specs, plan_for
from repro.models.config import applicable_shapes, skipped_shapes
from repro.models.transformer import build_model
from repro.optim import adamw
from repro.train.step import TrainConfig, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """Map computation name → body lines of the optimized HLO module."""
    comps: dict[str, list[str]] = {}
    current = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        # computation headers are unindented `[ENTRY] %name (args...) -> T {`
        # (args may contain nested tuple parens, so match only the prefix)
        if line and not line.startswith(" ") and s.endswith("{") and \
                "->" in s and "=" not in s.split("(")[0]:
            name = s.split("(")[0].replace("ENTRY", "").strip()
            name = name.lstrip("%").strip()
            if name:
                current = name
                comps[current] = []
                continue
        if s == "}":
            continue
        if current is not None:
            comps[current].append(s)
    return comps


def _type_bytes(type_str: str) -> int:
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for dim in dims.split(","):
                if dim:
                    n *= int(dim)
        nbytes += n * _DTYPE_BYTES[dt]
    return nbytes


def _trip_count(cond_lines: list[str]) -> int:
    """Trip count of a scan-generated while loop = the comparison constant
    in its condition computation (max int constant as a safe fallback)."""
    best = 1
    for line in cond_lines:
        m = re.search(r"constant\((\d+)\)", line)
        if m:
            best = max(best, int(m.group(1)))
    return best


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Per-kind collective payload bytes, with while-loop bodies multiplied
    by their trip counts (lax.scan over layers/microbatches lowers to while,
    whose body executes trip-count times but appears once in the text)."""
    comps = _split_computations(hlo)
    entry = None
    for name in comps:
        if name.startswith("main") or ".main" in name:
            entry = name
    if entry is None and comps:
        entry = list(comps)[-1]

    totals: dict[str, float] = {}
    counts: dict[str, float] = {}

    def walk(comp: str, mult: float, depth: int = 0):
        if comp not in comps or depth > 12:
            return
        for line in comps[comp]:
            m = re.search(
                r"=\s*(.+?)\s+(" + "|".join(_KINDS) + r")(-start)?\(", line)
            if m and "-done(" not in line:
                kind = m.group(2)
                nbytes = _type_bytes(m.group(1))
                totals[kind] = totals.get(kind, 0) + nbytes * mult
                counts[kind] = counts.get(kind, 0) + mult
            w = re.search(r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*"
                          r"body=%?([\w\.\-]+)", line)
            if w:
                cond, body = w.group(1), w.group(2)
                trips = _trip_count(comps.get(cond, []))
                walk(body, mult * trips, depth + 1)
            c = re.search(r"(?:calls|branch_computations)=.?\{?%?([\w\.\-]+)",
                          line)
            if c and "while(" not in line:
                walk(c.group(1), mult, depth + 1)
    walk(entry, 1.0)
    return {"bytes_by_kind": {k: int(v) for k, v in totals.items()},
            "count_by_kind": {k: int(v) for k, v in counts.items()},
            "total_bytes": int(sum(totals.values()))}


def _memory_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_size_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_size_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_size_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_size_bytes":
                int(getattr(ma, "generated_code_size_in_bytes", 0)),
            "alias_size_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        }
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def _cost_stats(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def quantized_param_shardings(mesh, aparams, arch):
    """Shardings for the packed-int4 serving param tree: packed weights
    shard like their bf16 counterparts (column-parallel on N for in-projs,
    row-parallel on K/2 for out-projs — nibble pairs stay on one shard
    because K is even per shard)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def spec(path, leaf):
        parts = path.split("/")
        name = parts[-2] if parts[-1] in ("packed", "scale") else parts[-1]
        mdl = "model" if "model" in mesh.axis_names else None
        if parts[-1] == "packed":
            if name in ("wq", "wk", "wv", "w_gate", "w_up"):
                return SH._fit(P(None, None, mdl), leaf.shape, mesh)
            return SH._fit(P(None, mdl, None), leaf.shape, mesh)
        if parts[-1] == "scale" and name in ("wq", "wk", "wv", "w_gate",
                                             "w_up"):
            return SH._fit(P(None, mdl), leaf.shape, mesh)
        if name == "embed":
            return SH._fit(P(mdl, None), leaf.shape, mesh)
        if name == "lm_head":
            return SH._fit(P(None, mdl), leaf.shape, mesh)
        return P(*([None] * len(leaf.shape)))

    paths, leaves, treedef = SH._tree_paths(aparams)
    return jax.tree_util.tree_unflatten(
        treedef, [NamedSharding(mesh, spec(p, l))
                  for p, l in zip(paths, leaves)])


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               save_hlo: bool = False, serve_layout: bool = False,
               remat_policy: str = "nothing",
               microbatches: int | None = None,
               moment_dtype: str = "float32",
               quantized_serve: bool = False) -> dict:
    cfg = get_config(arch)
    cells = {c.name: c for c in applicable_shapes(cfg)}
    if shape_name not in cells:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": skipped_shapes(cfg).get(shape_name, "n/a")}
    plan = plan_for(cfg, cells[shape_name])
    if microbatches is not None and plan.kind == "train":
        import dataclasses as _dc
        plan = _dc.replace(plan, num_microbatches=microbatches)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg, remat_policy=remat_policy)

    # serving layout (§Perf): replicated batch + 2D weights + fully-sharded
    # cache, so decode never all-gathers ZeRO-3 weights
    serve = serve_layout and plan.kind == "decode"
    rules = SH.SERVE_RULES if serve else None

    t0 = time.perf_counter()
    with mesh_context(mesh, rules=rules):
        aparams = model.init_abstract()
        pshard = SH.param_shardings(mesh, aparams, arch)
        specs = input_specs(model, plan)

        if plan.kind == "train":
            opt_cfg = adamw.AdamWConfig(moment_dtype=moment_dtype)
            aopt = jax.eval_shape(
                lambda p: adamw.init_state(opt_cfg, p), aparams)
            oshard = SH.opt_state_shardings(mesh, aopt, aparams, arch)
            bshard = SH.batch_shardings(mesh, specs["batch"])
            step = make_train_step(
                model, opt_cfg,
                TrainConfig(num_microbatches=plan.num_microbatches,
                            remat=True),
                param_shardings=pshard)
            jitted = jax.jit(
                step,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, SH.replicated(mesh)),
                donate_argnums=(0, 1))
            lowered = jitted.lower(aparams, aopt, specs["batch"])
        elif plan.kind == "prefill":
            bshard = SH.batch_shardings(mesh, specs["batch"])
            if "cache" in specs:
                cshard = SH.cache_shardings(mesh, specs["cache"])

                def prefill(p, b, c):
                    return model.prefill(p, b, c)

                jitted = jax.jit(prefill,
                                 in_shardings=(pshard, bshard, cshard),
                                 out_shardings=(SH.replicated(mesh), cshard),
                                 donate_argnums=(2,))
                lowered = jitted.lower(aparams, specs["batch"],
                                       specs["cache"])
            else:
                def encode(p, b):
                    return model.forward(p, b)

                jitted = jax.jit(encode, in_shardings=(pshard, bshard))
                lowered = jitted.lower(aparams, specs["batch"])
        elif plan.kind == "decode" and quantized_serve:
            from repro.kernels import ops as kops
            from repro.serve.quantized import QuantizedDenseLM, \
                pack_dense_params
            qlm = QuantizedDenseLM(cfg, block_size=32)
            aq = jax.eval_shape(lambda p: pack_dense_params(p, cfg), aparams)
            qshard = quantized_param_shardings(mesh, aq, arch)
            cspec = jax.eval_shape(
                lambda: qlm.init_cache(plan.cell.global_batch,
                                       plan.cell.seq_len))
            cshard = SH.cache_shardings(mesh, cspec)
            tshard = SH.batch_shardings(mesh, {"t": specs["tokens"]})["t"]

            def qdecode(p, t, c, i):
                # force the jnp reference path: the roofline reads op-level
                # FLOP/byte counts from the XLA graph, which interpret-mode
                # Pallas calls would obscure
                with kops.use_kernels(False):
                    return qlm.decode_step(p, t, c, i)

            jitted = jax.jit(qdecode,
                             in_shardings=(qshard, tshard, cshard,
                                           SH.replicated(mesh)),
                             out_shardings=(None, cshard),
                             donate_argnums=(2,))
            lowered = jitted.lower(aq, specs["tokens"], cspec,
                                   jax.ShapeDtypeStruct((), jnp.int32))
        else:  # decode
            if serve:
                cshard = SH.serve_cache_shardings(mesh, specs["cache"])
                tshard = SH.replicated(mesh)
            else:
                cshard = SH.cache_shardings(mesh, specs["cache"])
                tshard = SH.batch_shardings(mesh, {"t": specs["tokens"]})["t"]

            def decode(p, t, c, i):
                return model.decode_step(p, t, c, i)

            jitted = jax.jit(decode,
                             in_shardings=(pshard, tshard, cshard,
                                           SH.replicated(mesh)),
                             out_shardings=(None, cshard),
                             donate_argnums=(2,))
            lowered = jitted.lower(aparams, specs["tokens"], specs["cache"],
                                   jax.ShapeDtypeStruct((), jnp.int32))

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

        hlo = compiled.as_text()
        coll = collective_bytes_from_hlo(hlo)
        from repro.launch.hlo_analysis import analyze_hlo
        try:
            costs = analyze_hlo(hlo)
            hlo_costs = {
                "flops_per_device": costs.flops,
                "bytes_per_device": costs.bytes_accessed,
                "collective_bytes_by_kind": costs.collective_bytes,
                "collective_counts": costs.collective_counts,
                "top_dots": costs.dot_details[:12],
            }
        except Exception as e:  # noqa: BLE001
            hlo_costs = {"error": str(e)}
        out = {
            "arch": arch,
            "shape": shape_name,
            "kind": plan.kind,
            "multi_pod": multi_pod,
            "mesh": {"shape": list(mesh.devices.shape),
                     "axes": list(mesh.axis_names)},
            "num_microbatches": plan.num_microbatches,
            "remat_policy": remat_policy,
            "moment_dtype": moment_dtype,
            "serve_layout": serve,
            "quantized_serve": quantized_serve,
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": _memory_stats(compiled),
            "cost": _cost_stats(compiled),
            "collectives": coll,
            "hlo_costs": hlo_costs,
        }
        if save_hlo:
            out["hlo_path"] = _save_hlo(arch, shape_name, multi_pod, hlo)
        return out


def _save_hlo(arch, shape, multi_pod, hlo: str) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(
        ARTIFACT_DIR, f"{arch}__{shape}__{'mp' if multi_pod else 'sp'}.hlo")
    with open(path, "w") as f:
        f.write(hlo)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id (default all)")
    ap.add_argument("--shape", default=None, help="single shape name")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--serve-layout", action="store_true",
                    help="replicated-batch serving layout for decode cells")
    ap.add_argument("--remat-policy", default="nothing",
                    choices=["nothing", "dots"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--moment-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--quantized-serve", action="store_true",
                    help="lower the packed-int4 integer decode path")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else \
        ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                tag = f"{arch}/{shape}/{'2pod' if mp else '1pod'}"
                try:
                    rec = lower_cell(arch, shape, multi_pod=mp,
                                     save_hlo=args.save_hlo,
                                     serve_layout=args.serve_layout,
                                     remat_policy=args.remat_policy,
                                     microbatches=args.microbatches,
                                     moment_dtype=args.moment_dtype,
                                     quantized_serve=args.quantized_serve)
                except Exception:
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "fail",
                           "error": traceback.format_exc(limit=20)}
                if rec["status"] == "ok":
                    n_ok += 1
                    mem = rec["memory"]
                    per_dev = (mem.get("argument_size_bytes", 0)
                               + mem.get("temp_size_bytes", 0)) / 2 ** 30
                    print(f"[OK]   {tag:60s} lower {rec['lower_s']:6.1f}s "
                          f"compile {rec['compile_s']:6.1f}s "
                          f"arg+temp/dev {per_dev:7.2f} GiB "
                          f"coll {rec['collectives']['total_bytes']/2**30:8.3f} GiB")
                elif rec["status"] == "skipped":
                    n_skip += 1
                    print(f"[SKIP] {tag:60s} {rec['reason']}")
                else:
                    n_fail += 1
                    print(f"[FAIL] {tag}")
                    print(rec["error"])
                fname = f"{arch}__{shape}__{'mp' if mp else 'sp'}" + \
                    ("__serve" if args.serve_layout and
                     rec.get("kind") == "decode" else "") + \
                    (f"__{args.tag}" if args.tag else "") + ".json"
                with open(os.path.join(args.out, fname), "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
