"""Trip-count-aware cost analysis of optimized (SPMD-partitioned) HLO text.

XLA's built-in `compiled.cost_analysis()` visits every computation once, so
`lax.scan`-generated while bodies (our layer stack and microbatch loops) are
undercounted by their trip counts. This walker:

  * splits the module into computations,
  * builds a per-computation symbol table (op name → type string) including
    computation parameters,
  * walks the call graph from ENTRY, multiplying by while trip counts
    (read from the loop-condition comparison constant),
  * accounts per executed op:
      - FLOPs for dot/convolution (2·|out|·K from the contracting dims),
      - HBM traffic for materializing ops (operands + output bytes;
        tuple/GTE/bitcast/parameter/constant are free),
      - collective payload bytes by kind.

Shapes in a partitioned module are per-device shards, so every number this
module reports is **per device**.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_FREE_OPS = {"tuple", "get-tuple-element", "bitcast", "parameter",
             "constant", "after-all", "custom-call"}


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x] if dims else []
        out.append((dt, d))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(type_str: str) -> list[int] | None:
    s = _shape_dims(type_str)
    return s[0][1] if s else None


@dataclasses.dataclass
class Computation:
    name: str
    header: str
    lines: list[str]
    symbols: dict[str, str]          # op/param name → type string


def split_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if line and not line.startswith(" ") and s.endswith("{") \
                and "->" in s and "=" not in s.split("(")[0]:
            name = s.split("(")[0].replace("ENTRY", "").strip().lstrip("%")
            current = Computation(name=name, header=s, lines=[], symbols={})
            comps[name] = current
            # parse parameters: `%p: TYPE` pairs inside the header
            for pm in re.finditer(r"%?([\w\.\-]+):\s*((?:\([^)]*\)|"
                                  r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))",
                                  s):
                current.symbols[pm.group(1)] = pm.group(2)
            continue
        if s == "}":
            continue
        if current is None:
            continue
        current.lines.append(s)
        dm = re.match(r"%?([\w\.\-]+)\s*=\s*((?:\([^=]*?\)|\S+))\s+"
                      r"([\w\-]+)", s)
        if dm:
            current.symbols[dm.group(1)] = dm.group(2)
    return comps


_DEF_RE = re.compile(
    r"^%?([\w\.\-]+)\s*=\s*(.*?)\s+([a-z][\w\-]*)\((.*)$")


def _operands(rest: str) -> list[str]:
    """Operand names from the text after the opening paren (first level)."""
    names = []
    depth = 0
    token = ""
    for ch in rest:
        if ch == "(" or ch == "{" or ch == "[":
            depth += 1
        elif ch == ")" or ch == "}" or ch == "]":
            if ch == ")" and depth == 0:
                break
            depth -= 1
        if depth == 0 and ch == ",":
            names.append(token)
            token = ""
        else:
            token += ch
    names.append(token)
    out = []
    for t in names:
        m = re.search(r"%([\w\.\-]+)", t)
        if m:
            out.append(m.group(1))
    return out


def _trip_count(cond: Computation | None) -> int:
    if cond is None:
        return 1
    best = 1
    for line in cond.lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    collective_counts: dict = dataclasses.field(default_factory=dict)
    dot_details: list = dataclasses.field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze_hlo(hlo: str, *, keep_top_dots: int = 24) -> HloCosts:
    comps = split_module(hlo)
    entry = None
    for name, c in comps.items():
        if "ENTRY" in c.header:
            entry = name
    if entry is None:
        for name in comps:
            if name.startswith("main") or ".main" in name:
                entry = name
    if entry is None:
        raise ValueError("no ENTRY computation found")

    costs = HloCosts()
    dot_acc: dict[str, float] = defaultdict(float)

    def walk(comp_name: str, mult: float, depth: int = 0):
        comp = comps.get(comp_name)
        if comp is None or depth > 16:
            return
        for line in comp.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            out_name, out_type, op, rest = dm.groups()
            if op in _FREE_OPS:
                continue

            # control flow
            if op == "while":
                w = re.search(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)",
                              line)
                if w:
                    trips = _trip_count(comps.get(w.group(1)))
                    walk(w.group(2), mult * trips, depth + 1)
                    walk(w.group(1), mult * trips, depth + 1)
                continue
            if op in ("conditional", "call", "fusion", "reduce", "sort",
                      "scatter", "select-and-scatter", "reduce-window",
                      "map", "reduce-scatter", "all-reduce"):
                for cm in re.finditer(r"(?:calls|to_apply|"
                                      r"branch_computations)=\{?%?"
                                      r"([\w\.\-]+)", line):
                    # reducers/fused bodies are elementwise-cheap; recurse
                    # only for call/conditional which contain real work
                    if op in ("call", "conditional"):
                        walk(cm.group(1), mult, depth + 1)

            # collectives
            for kind in _COLLECTIVES:
                if op.startswith(kind):
                    if op.endswith("-done"):
                        break
                    b = _type_bytes(out_type)
                    costs.collective_bytes[kind] = \
                        costs.collective_bytes.get(kind, 0) + b * mult
                    costs.collective_counts[kind] = \
                        costs.collective_counts.get(kind, 0) + mult
                    break

            # memory traffic: operands + output. dynamic-update-slice on a
            # donated buffer is in-place: charge only the update payload
            # (counting the full cache per decode step would claim ~2× the
            # true HBM traffic).
            if op == "dynamic-update-slice":
                ops_ = _operands(rest)
                upd_t = comp.symbols.get(ops_[1]) if len(ops_) > 1 else None
                nbytes = 2 * (_type_bytes(upd_t) if upd_t else 0)
            else:
                nbytes = _type_bytes(out_type)
                for operand in _operands(rest):
                    t = comp.symbols.get(operand)
                    if t:
                        nbytes += _type_bytes(t)
            costs.bytes_accessed += nbytes * mult

            # FLOPs: dot / convolution
            if op == "dot":
                ops = _operands(rest)
                lhs_t = comp.symbols.get(ops[0]) if ops else None
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
                k = 1
                if lhs_t and cdims:
                    lshape = _first_shape(lhs_t) or []
                    for ds in cdims.group(1).split(","):
                        if ds and int(ds) < len(lshape):
                            k *= lshape[int(ds)]
                out_shape = _first_shape(out_type) or []
                n_out = 1
                for dd in out_shape:
                    n_out *= dd
                f = 2.0 * n_out * k * mult
                costs.flops += f
                sig = f"dot {lhs_t} x ... -> {out_type.split('{')[0]}"
                dot_acc[sig] += f
            elif op == "convolution":
                # depthwise/1d convs in this codebase are tiny; estimate
                # 2·|out|·window from the kernel operand if available
                ops = _operands(rest)
                ker_t = comp.symbols.get(ops[1]) if len(ops) > 1 else None
                window = 1
                if ker_t:
                    ks = _first_shape(ker_t) or []
                    for dd in ks[:-2] or ks:
                        window *= dd
                out_shape = _first_shape(out_type) or []
                n_out = 1
                for dd in out_shape:
                    n_out *= dd
                costs.flops += 2.0 * n_out * window * mult

    walk(entry, 1.0)
    costs.dot_details = sorted(dot_acc.items(), key=lambda kv: -kv[1])
    costs.dot_details = costs.dot_details[:keep_top_dots]
    return costs
