"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — dryrun.py must set XLA_FLAGS before any jax
device query, and tests/benches must keep seeing 1 device.
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)            # 256 chips (v5e pod)
MULTI_POD = (2, 16, 16)          # 2 pods = 512 chips


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def device_count_required(multi_pod: bool) -> int:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    n = 1
    for s in shape:
        n *= s
    return n
