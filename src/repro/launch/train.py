"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-1b --reduced \\
        --steps 200 --batch 16 --seq 64 --workdir /tmp/run1

Runs the fault-tolerant driver (retries, periodic checkpoints, straggler
EWMA) over the synthetic or file-backed corpus. On a real multi-host TPU
deployment the same entry point runs under `jax.distributed.initialize()`
with the production mesh; on this host it runs single-device (or under
`--host-devices N` for a local mesh).
"""
import argparse
import os

# must precede any jax import/device query
_hd = os.environ.get("REPRO_HOST_DEVICES")
if _hd:
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={_hd}"

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.pipeline import (ByteCorpus, DataConfig, Prefetcher,
                                 SyntheticCorpus, batch_iterator)
from repro.distributed import shardings as SH
from repro.distributed.context import mesh_context
from repro.models.transformer import build_model
from repro.optim import adamw
from repro.runtime.driver import ElasticMesh, RuntimeConfig, TrainDriver
from repro.train.step import TrainConfig, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-1b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale reduced config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--corpus", default=None,
                    help="path to a byte corpus (default: synthetic)")
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)

    mesh = None
    if args.model_parallel > 1 or jax.device_count() > 1:
        mesh = ElasticMesh(args.model_parallel).make()

    corpus = ByteCorpus(args.corpus) if args.corpus else \
        SyntheticCorpus(cfg.vocab, seed=0)
    data_cfg = DataConfig(cfg.vocab, args.seq, args.batch,
                          host_id=jax.process_index(),
                          num_hosts=jax.process_count())
    it = Prefetcher(batch_iterator(corpus, data_cfg))

    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps)

    def run():
        params = model.init(jax.random.PRNGKey(0))
        pshard = None
        if mesh is not None:
            pshard = SH.param_shardings(mesh, params, cfg.name)
            params = jax.tree.map(jax.device_put, params, pshard)
        opt = adamw.init_state(opt_cfg, params)
        step = jax.jit(make_train_step(
            model, opt_cfg,
            TrainConfig(num_microbatches=args.microbatches,
                        remat=args.remat),
            param_shardings=pshard))
        mgr = CheckpointManager(os.path.join(args.workdir, "ckpt"))
        start = 0
        if mgr.latest_step() is not None:
            restored = mgr.restore(target={"params": params, "opt": opt})
            params, opt = restored["params"], restored["opt"]
            start = mgr.latest_step()
            print(f"resumed from step {start}")
        else:
            mgr.save(0, {"params": params, "opt": opt}, blocking=True)
        driver = TrainDriver(step, mgr, RuntimeConfig(
            checkpoint_every=args.checkpoint_every))

        def report(s, state):
            if s % 20 == 0:
                print(f"step {s:6d}  ewma {driver.stats.ewma*1e3:8.1f} ms"
                      f"  stragglers {len(driver.stats.stragglers)}")

        (params, opt), end = driver.run(params, opt, it,
                                        start_step=start,
                                        num_steps=args.steps,
                                        on_metrics=report)
        print(f"finished at step {end}; failures={driver.failures} "
              f"restores={driver.restores}")
        return params

    if mesh is not None:
        with mesh_context(mesh):
            run()
    else:
        run()
    it.close()


if __name__ == "__main__":
    main()
