"""AdamW with decoupled weight decay, global-norm clipping, and LR schedules.

Self-contained (no optax in this container). Optimizer state dtypes are
configurable so large archs can run bf16 moments (halves the ZeRO-3 optimizer
footprint; see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"   # "bfloat16" halves optimizer memory
    schedule: str = "cosine"        # "cosine" | "linear" | "constant"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 \
            * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_ratio) * frac
    else:
        decay = jnp.asarray(1.0)
    return cfg.lr * warm * decay


def init_state(cfg: AdamWConfig, params: Params) -> dict:
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    def zeros(p):
        return jnp.zeros(p.shape, mdt)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def apply_updates(cfg: AdamWConfig, params: Params, grads: Params,
                  state: dict) -> tuple[Params, dict, dict]:
    """One AdamW step. Returns (params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.asarray(1.0)
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay > 0:  # no decay on norms/bias
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
