"""Training step factory: microbatched gradient accumulation, remat,
optional int8 error-feedback gradient compression on the cross-pod reduce.

The returned `train_step(params, opt_state, batch)` is pjit-ready: all
cross-device communication is expressed through shardings (GSPMD), and the
microbatch loop is a `lax.scan` so the compiled HLO stays compact.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim import adamw
from repro.distributed.compression import ef_compress_grads

Params = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    num_microbatches: int = 1
    remat: bool = True
    compress_grads: bool = False   # int8 error-feedback on the DP reduce
    compress_axis: str = "pod"


def make_train_step(model, opt_cfg: adamw.AdamWConfig,
                    train_cfg: TrainConfig,
                    param_shardings: Params | None = None) -> Callable:
    n_micro = train_cfg.num_microbatches

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch,
                                         remat=train_cfg.remat)
        return loss, metrics, grads

    def constrain(tree):
        # Pin the microbatch gradient accumulator to the parameter layout.
        # Without this the scan carry is unconstrained and GSPMD replicates
        # it — every microbatch then all-gathers full weight-shaped f32
        # gradients (measured 6.7 TiB/device/step on llama4-400B; §Perf A1).
        if param_shardings is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            tree, param_shardings)

    def train_step(params: Params, opt_state: dict, batch: Params,
                   ef_state: Params | None = None):
        if n_micro > 1:
            def reshape(x):
                b = x.shape[0]
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            micro = jax.tree.map(reshape, batch)

            def body(carry, mb):
                acc, loss_acc = carry
                loss, metrics, grads = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (constrain(acc), loss_acc + loss), metrics

            zeros = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (gsum, loss_sum), metrics = jax.lax.scan(
                body, (zeros, jnp.asarray(0.0, jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = loss_sum / n_micro
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            loss, metrics, grads = grads_of(params, batch)

        new_ef = ef_state
        if train_cfg.compress_grads and ef_state is not None:
            grads, new_ef = ef_compress_grads(grads, ef_state,
                                              axis=train_cfg.compress_axis)

        params, opt_state, om = adamw.apply_updates(opt_cfg, params, grads,
                                                    opt_state)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        if train_cfg.compress_grads and ef_state is not None:
            return params, opt_state, new_ef, metrics
        return params, opt_state, metrics

    return train_step


def make_eval_step(model) -> Callable:
    def eval_step(params: Params, batch: Params):
        loss, metrics = model.loss_fn(params, batch, remat=False)
        return metrics

    return eval_step
