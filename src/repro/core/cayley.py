"""Learned rotations via the Cayley transform (SpinQuant-style, for PeRQ†).

SpinQuant optimizes orthogonal R₁/R₂ with Cayley SGD on the Stiefel manifold.
We use the equivalent skew parametrization: R(A) = (I − A)(I + A)⁻¹ · R₀ with
A skew-symmetric and R₀ a Hadamard initialization; plain Adam on the free
entries of A keeps R exactly orthogonal at every step. Gradients flow through
the quantizers with the straight-through estimator (Bengio et al. 2013),
matching Appendix B ("Cayley SGD after both weights and activations have been
quantized using STE").
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["cayley", "skew", "learn_rotation"]


def skew(a_free: jnp.ndarray, d: int) -> jnp.ndarray:
    """Build a skew-symmetric matrix from d(d−1)/2 free parameters."""
    iu = jnp.triu_indices(d, k=1)
    A = jnp.zeros((d, d), a_free.dtype).at[iu].set(a_free)
    return A - A.T


def cayley(a: jnp.ndarray) -> jnp.ndarray:
    """Cayley transform: (I − A)(I + A)⁻¹, orthogonal for skew A."""
    d = a.shape[0]
    eye = jnp.eye(d, dtype=a.dtype)
    return jax.scipy.linalg.solve(eye + a, (eye - a).T, assume_a="gen").T


def learn_rotation(loss_fn: Callable[[jnp.ndarray], jnp.ndarray], d: int,
                   *, r0: jnp.ndarray | None = None, steps: int = 100,
                   lr: float = 1e-2, beta1: float = 0.9, beta2: float = 0.999,
                   eps: float = 1e-8) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Minimize loss_fn(R) over orthogonal R = cayley(skew(a))·R₀ with Adam.

    Returns (R_opt, loss_history[steps]).
    """
    if r0 is None:
        r0 = jnp.eye(d, dtype=jnp.float32)
    n_free = d * (d - 1) // 2
    a0 = jnp.zeros((n_free,), jnp.float32)

    def full_loss(a_free):
        r = cayley(skew(a_free, d)) @ r0
        return loss_fn(r)

    grad_fn = jax.jit(jax.value_and_grad(full_loss))

    def step(carry, _):
        a, m, v, t = carry
        loss, g = grad_fn(a)
        t = t + 1
        m = beta1 * m + (1 - beta1) * g
        v = beta2 * v + (1 - beta2) * g * g
        mhat = m / (1 - beta1 ** t)
        vhat = v / (1 - beta2 ** t)
        a = a - lr * mhat / (jnp.sqrt(vhat) + eps)
        return (a, m, v, t), loss

    init = (a0, jnp.zeros_like(a0), jnp.zeros_like(a0), jnp.asarray(0, jnp.float32))
    (a, _, _, _), hist = jax.lax.scan(step, init, None, length=steps)
    r = cayley(skew(a, d)) @ r0
    return r, hist
