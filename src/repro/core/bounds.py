"""Executable forms of the paper's theoretical analysis (Section 3).

These are used three ways:
  1. property tests (the bounds must hold for arbitrary inputs — hypothesis),
  2. the Figure 3/4/5 benchmark harnesses,
  3. MassDiff diagnostics (the Prop-3.2 bound is the optimization target).
"""
from __future__ import annotations

import math

import jax.numpy as jnp

__all__ = [
    "mass_concentration",
    "energy_concentration",
    "block_mass_concentration",
    "prop31_bound",
    "prop32_bound",
    "zeta",
    "cor33_rhs",
    "prop34_bound",
    "suppression_ratio",
    "sufficient_threshold_full",
    "sufficient_threshold_block",
]


def _blocks(x: jnp.ndarray, b: int) -> jnp.ndarray:
    d = x.shape[-1]
    if d % b:
        raise ValueError(f"d={d} not divisible by b={b}")
    return x.reshape(*x.shape[:-1], d // b, b)


def mass_concentration(x: jnp.ndarray) -> jnp.ndarray:
    """δ = ‖X‖₁ / (d·‖X‖∞) ∈ [1/d, 1] over the last axis."""
    d = x.shape[-1]
    l1 = jnp.sum(jnp.abs(x), axis=-1)
    linf = jnp.max(jnp.abs(x), axis=-1)
    return l1 / (d * jnp.maximum(linf, jnp.finfo(jnp.float32).tiny))


def energy_concentration(x: jnp.ndarray) -> jnp.ndarray:
    """δ' = ‖X‖₂ / (√d·‖X‖∞) ∈ [1/√d, 1] (Remark D.1)."""
    d = x.shape[-1]
    l2 = jnp.linalg.norm(x, axis=-1)
    linf = jnp.max(jnp.abs(x), axis=-1)
    return l2 / (math.sqrt(d) * jnp.maximum(linf, jnp.finfo(jnp.float32).tiny))


def block_mass_concentration(x: jnp.ndarray, b: int) -> jnp.ndarray:
    """δ_{j} per block: [..., n]."""
    g = _blocks(x, b)
    l1 = jnp.sum(jnp.abs(g), axis=-1)
    linf = jnp.max(jnp.abs(g), axis=-1)
    return l1 / (b * jnp.maximum(linf, jnp.finfo(jnp.float32).tiny))


def prop31_bound(x: jnp.ndarray) -> jnp.ndarray:
    """Prop 3.1 RHS: δ·√d·‖X‖∞ = ‖X‖₁/√d."""
    d = x.shape[-1]
    return jnp.sum(jnp.abs(x), axis=-1) / math.sqrt(d)


def prop32_bound(x: jnp.ndarray, b: int) -> jnp.ndarray:
    """Prop 3.2 RHS: max_j δ_{j}·√b·‖X_{j}‖∞ = max_j ‖X_{j}‖₁/√b."""
    g = _blocks(x, b)
    return jnp.max(jnp.sum(jnp.abs(g), axis=-1), axis=-1) / math.sqrt(b)


def zeta(x: jnp.ndarray, b: int) -> jnp.ndarray:
    """Z(b; X) of Corollary 3.3 (identical to the Prop-3.2 RHS)."""
    return prop32_bound(x, b)


def cor33_rhs(x: jnp.ndarray, b_small: int, k: int) -> jnp.ndarray:
    """√k · Z(b'; X) — Corollary 3.3 guarantees Z(k·b'; X) ≤ this."""
    return math.sqrt(k) * zeta(x, b_small)


def prop34_bound(x: jnp.ndarray, b: int, eps: float,
                 *, tight: bool = True) -> jnp.ndarray:
    """Prop 3.4 RHS at confidence 1−ε.

    tight=True uses the per-block energy form from the proof
    (√(2/b·log(2d/ε)·max_j ‖X_{j}‖₂²)); tight=False uses the looser
    main-text form with ‖X‖₂².
    """
    d = x.shape[-1]
    c = 2.0 / b * math.log(2.0 * d / eps)
    if tight:
        g = _blocks(x, b)
        e = jnp.max(jnp.sum(g * g, axis=-1), axis=-1)
    else:
        e = jnp.sum(x * x, axis=-1)
    return jnp.sqrt(c * e)


def suppression_ratio(x: jnp.ndarray, xr: jnp.ndarray) -> jnp.ndarray:
    """‖XR‖∞ / ‖X‖∞ (< 1 ⇔ outliers suppressed)."""
    num = jnp.max(jnp.abs(xr), axis=-1)
    den = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), jnp.finfo(jnp.float32).tiny)
    return num / den


def sufficient_threshold_full(d: int) -> float:
    """δ < 1/√d guarantees suppression for full-vector rotations."""
    return 1.0 / math.sqrt(d)


def sufficient_threshold_block(b: int) -> float:
    """max_j δ_{j}‖X_{j}‖∞/‖X‖∞ < 1/√b guarantees suppression (block)."""
    return 1.0 / math.sqrt(b)
