"""Permutation-equivariant region merging (Def 4.1 / Remark 4.2) and
rotation merging (QuaRot-style R₁/R₂), at the weight-matrix level.

The model-tree walkers that apply these to whole networks live in
`repro.core.pipeline`; everything here is pure linear algebra on individual
weights so it can be property-tested in isolation.

Weight convention: ``y = x @ W + b`` with ``W: [d_in, d_out]``.

A permutation ``perm`` follows the `massdiff` convention:
``permuted_x = x[..., perm]`` ⇔ ``x @ P`` with ``P = I[:, perm]``.
To make a *producer* emit permuted features: ``W ← W[:, perm]`` (and b[perm]).
To make a *consumer* accept permuted features: ``W ← W[perm, :]``.
Then (x W₁)[...,perm] @ W₂[perm,:] == x W₁ W₂ — the graph is unchanged.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "permute_producer",
    "permute_consumer",
    "merge_perm_into_ffn",
    "rotate_producer",
    "rotate_consumer",
    "merge_head_rotation",
    "fold_rmsnorm",
    "center_matrix",
    "fold_layernorm_center",
]


# -- permutations -----------------------------------------------------------

def permute_producer(w: jnp.ndarray, perm, bias: jnp.ndarray | None = None):
    """Producer emits permuted features: W[:, perm] (+ b[perm])."""
    perm = jnp.asarray(perm)
    wp = w[..., perm]
    bp = bias[..., perm] if bias is not None else None
    return (wp, bp) if bias is not None else wp


def permute_consumer(w: jnp.ndarray, perm):
    """Consumer accepts permuted features: W[perm, :]."""
    perm = jnp.asarray(perm)
    return jnp.take(w, perm, axis=-2)


def merge_perm_into_ffn(w_gate, w_up, w_down, perm,
                        b_gate=None, b_up=None):
    """Fig. 6: permute the FFN hidden dim. Swish/⊙ are elementwise so the
    region Φ(x) = swish(xW_g)⊙(xW_u) is permutation-equivariant; P merges
    into W_g, W_u (producers) and W_d (consumer)."""
    w_gate = permute_producer(w_gate, perm)
    w_up = permute_producer(w_up, perm)
    w_down = permute_consumer(w_down, perm)
    out = [w_gate, w_up, w_down]
    if b_gate is not None:
        out.append(b_gate[..., jnp.asarray(perm)])
    if b_up is not None:
        out.append(b_up[..., jnp.asarray(perm)])
    return tuple(out)


# -- rotations --------------------------------------------------------------

def rotate_producer(w: jnp.ndarray, r: jnp.ndarray,
                    bias: jnp.ndarray | None = None):
    """Producer emits rotated features: W ← W @ R (+ b ← b @ R)."""
    wr = w @ r
    if bias is not None:
        return wr, bias @ r
    return wr


def rotate_consumer(w: jnp.ndarray, r: jnp.ndarray):
    """Consumer accepts rotated features: W ← Rᵀ @ W (orthogonal R)."""
    return r.T @ w


def merge_head_rotation(w_v: jnp.ndarray, w_o: jnp.ndarray, r: jnp.ndarray,
                        n_kv_heads: int, n_q_heads: int):
    """R₂ (per-head rotation between V and O projections).

    w_v: [d, n_kv_heads·h], w_o: [n_q_heads·h, d], r: [h, h]. Each head's
    value slice is rotated on output; each head's o-proj slice on input.
    GQA: query-head groups share a rotated KV head, so rotating every
    q-head's o-slice by the same R is consistent.
    """
    h = r.shape[0]
    d, _ = w_v.shape
    v = w_v.reshape(d, n_kv_heads, h) @ r
    o = jnp.einsum("hk,qkd->qhd", r.T, w_o.reshape(n_q_heads, h, -1))
    return v.reshape(w_v.shape), o.reshape(w_o.shape)


# -- norm folding -----------------------------------------------------------

def fold_rmsnorm(gamma: jnp.ndarray, consumers: list[jnp.ndarray]):
    """Fold the RMSNorm scale into the consuming projections:
    (x·γ) @ W == x @ (diag(γ)W). Returns (ones_like(γ), new_consumers)."""
    new = [gamma[:, None] * w for w in consumers]
    return jnp.ones_like(gamma), new


def center_matrix(d: int) -> np.ndarray:
    """M = I − 11ᵀ/d. LN(x) == RMSNorm(x @ M)·γ + β, so folding M into every
    producer of the residual stream converts LayerNorm to RMSNorm (QuaRot)."""
    return np.eye(d, dtype=np.float32) - np.full((d, d), 1.0 / d, np.float32)


def fold_layernorm_center(w_producer: jnp.ndarray) -> jnp.ndarray:
    """Apply the centering fold to a residual-stream producer: W ← W @ M.
    Implemented as a rank-1 update (no d×d matmul)."""
    mean = jnp.mean(w_producer, axis=-1, keepdims=True)
    return w_producer - mean
