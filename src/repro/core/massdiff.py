"""Permutation calibration: MassDiff (Algorithm 1) and baselines.

MassDiff greedily assigns coordinates (in descending average-magnitude order)
to the block whose running average ℓ₁ mass is smallest, equalizing the
expected per-block ℓ₁ norms — the quantity that governs the Prop-3.2 bound.

Baselines reproduced for Table 6: identity, random, absmax (descending sort),
and ZigZag (Lin et al. 2024a — serpentine round-robin assignment).

Conventions
-----------
A permutation is an index array ``perm`` of shape [d] such that the permuted
vector is ``x[..., perm]`` — i.e. output coordinate i reads input coordinate
``perm[i]``. Block j then owns output coordinates [j·b, (j+1)·b).
The matching permutation matrix is ``P = I[:, perm]`` so ``x @ P == x[..., perm]``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "coordinate_mass",
    "massdiff",
    "massdiff_jax",
    "zigzag",
    "absmax",
    "random_permutation",
    "identity",
    "perm_matrix",
    "invert",
    "block_l1_norms",
    "make_permutation",
]


def coordinate_mass(calib: np.ndarray | jnp.ndarray) -> np.ndarray:
    """Average magnitude per coordinate over a calibration set.

    `calib` is [num_tokens, d] (tokens pooled over the calibration sequences);
    returns μ_i = (1/m)·Σ_k |X_i^{(k)}|, the per-coordinate mean mass. Because
    per-block ℓ₁ norms are additive in coordinates, Algorithm 1's expected
    max-block objective depends on the calibration data only through μ.
    """
    a = np.asarray(calib, dtype=np.float64)
    if a.ndim == 1:
        a = a[None, :]
    return np.mean(np.abs(a), axis=0)


def massdiff(mass: np.ndarray, block_size: int) -> np.ndarray:
    """Algorithm 1 (MassDiff): greedy mass diffusion.

    Sort coordinates by descending mean mass; assign each to the non-full
    block with the smallest running mass (LPT-style makespan balancing).
    Returns the permutation index array (see module docstring convention).
    """
    mass = np.asarray(mass, dtype=np.float64)
    d = mass.shape[0]
    if d % block_size:
        raise ValueError(f"d={d} not divisible by b={block_size}")
    n = d // block_size
    order = np.argsort(-mass, kind="stable")
    sums = np.zeros(n)
    members: list[list[int]] = [[] for _ in range(n)]
    open_sums = sums.copy()
    for i in order:
        j = int(np.argmin(open_sums))
        members[j].append(int(i))
        sums[j] += mass[i]
        open_sums[j] = sums[j]
        if len(members[j]) == block_size:
            open_sums[j] = np.inf
    perm = np.concatenate([np.asarray(m, dtype=np.int64) for m in members])
    return perm


def massdiff_jax(mass: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """jit-compatible MassDiff (lax.fori_loop) for large d on-device.

    Functionally identical to `massdiff` (up to argmin tie-breaking, which is
    `first index` in both).
    """
    d = mass.shape[0]
    n = d // block_size
    order = jnp.argsort(-mass, stable=True)

    def body(step, state):
        sums, counts, block_of = state
        i = order[step]
        eligible = counts < block_size
        j = jnp.argmin(jnp.where(eligible, sums, jnp.inf))
        sums = sums.at[j].add(mass[i])
        counts = counts.at[j].add(1)
        block_of = block_of.at[i].set(j)
        return sums, counts, block_of

    sums = jnp.zeros((n,), jnp.float32)
    counts = jnp.zeros((n,), jnp.int32)
    block_of = jnp.zeros((d,), jnp.int32)
    _, _, block_of = jax.lax.fori_loop(0, d, body, (sums, counts, block_of))
    # Coordinates sorted by (block, descending mass) → concatenated blocks.
    # Stable sort on block id over the descending-mass order reproduces the
    # per-block insertion order of the greedy loop.
    perm = order[jnp.argsort(block_of[order], stable=True)]
    return perm


def zigzag(mass: np.ndarray, block_size: int) -> np.ndarray:
    """ZigZag (Lin et al. 2024a): descending sort, serpentine round-robin.

    Coordinate ranks 0..d-1 are dealt across blocks 0,1,..,n-1,n-1,..,1,0,0,..
    so each block receives one coordinate per half-sweep.
    """
    mass = np.asarray(mass, dtype=np.float64)
    d = mass.shape[0]
    n = d // block_size
    order = np.argsort(-mass, kind="stable")
    fwd = np.arange(n)
    pattern = np.concatenate([fwd, fwd[::-1]])
    blocks = np.tile(pattern, d // (2 * n) + 1)[:d]
    members: list[list[int]] = [[] for _ in range(n)]
    for rank, i in enumerate(order):
        members[blocks[rank]].append(int(i))
    perm = np.concatenate([np.asarray(m, dtype=np.int64) for m in members])
    return perm


def absmax(calib: np.ndarray, block_size: int) -> np.ndarray:
    """Absmax baseline: descending order of max |x| over the calibration set,
    chunked into contiguous blocks."""
    a = np.asarray(calib)
    if a.ndim == 1:
        a = a[None, :]
    m = np.max(np.abs(a), axis=0)
    return np.argsort(-m, kind="stable").astype(np.int64)


def random_permutation(d: int, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).permutation(d).astype(np.int64)


def identity(d: int) -> np.ndarray:
    return np.arange(d, dtype=np.int64)


def perm_matrix(perm: np.ndarray) -> np.ndarray:
    """P such that x @ P == x[..., perm] (columns are unit vectors e_{perm[i]})."""
    d = len(perm)
    P = np.zeros((d, d), dtype=np.float32)
    P[np.asarray(perm), np.arange(d)] = 1.0
    return P


def invert(perm: np.ndarray) -> np.ndarray:
    inv = np.empty_like(np.asarray(perm))
    inv[np.asarray(perm)] = np.arange(len(perm))
    return inv


def block_l1_norms(x: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Per-block ℓ₁ norms over the last axis: [..., n]."""
    d = x.shape[-1]
    g = x.reshape(*x.shape[:-1], d // block_size, block_size)
    return jnp.sum(jnp.abs(g), axis=-1)


def make_permutation(method: str, calib: np.ndarray, block_size: int,
                     *, seed: int = 0) -> np.ndarray:
    """Dispatch: method ∈ {massdiff, zigzag, absmax, random, identity}."""
    calib = np.asarray(calib)
    d = calib.shape[-1]
    if method == "identity":
        return identity(d)
    if method == "random":
        return random_permutation(d, seed)
    if method == "absmax":
        return absmax(calib, block_size)
    mass = coordinate_mass(calib)
    if method == "massdiff":
        return massdiff(mass, block_size)
    if method == "zigzag":
        return zigzag(mass, block_size)
    raise ValueError(f"unknown permutation method {method!r}")
