"""PeRQ end-to-end PTQ pipeline (Figure 2 / Figure 7 of the paper).

Order of operations (all function-preserving until rounding):
  1. fold       — absorb norm scales into adjacent projections (and keep
                  the graph numerically identical), per family.
  2. calibrate  — capture projection-input activations per layer on the
                  folded model (so Hessians live in the runtime space).
  3. rotate     — merge R₁ (stream) and R₂ (per-head) per Remark 4.2;
                  R₁ is a full-vector Hadamard (QuaRot), a Cayley-learned
                  rotation (SpinQuant), or a block Hadamard (MR-GPTQ/BRQ).
  4. permute    — calibrate P₃ with MassDiff (Alg. 1) on the R̃₃-site
                  activations and merge it into the surrounding weights.
  5. round      — RTN / GPTQ / Qronos per projection with Hessians from the
                  transformed (and quantized) activations (Appendix B).
Runtime hooks: dynamic per-token activation quant on every projection input
+ the online block-Hadamard at R̃₃ — the only op left online.

Pipeline compositions (Table 2):
    perq_star    MassDiff + QuaRot R₁/R₂ + block R̃₃ + Qronos
    perq_dagger  MassDiff + SpinQuant(Cayley) R₁ + block R̃₃ + RTN
    mr_rtn/gptq/qronos   identity P + merged block R₁/R₂ + block R̃₃
    brq_spin     identity P + learned block R₁ + block R̃₃ + GPTQ
    quarot       identity P + full-vector rotations + Qronos (R̃₃ = full)

Family scope (DESIGN.md §Arch-applicability): dense/vlm/moe get the full
graph; encoder (LayerNorm stream) gets R̃₃+P₃ only; SSM gets R₁ on the
stream + R̃₃ at out_proj with head-preserving MassDiff.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model, build_model
from . import massdiff as MD
from . import rounding as RD
from .cayley import learn_rotation
from .equivariance import merge_head_rotation, permute_consumer, \
    permute_producer
from .hadamard import (block_hadamard_matrix, block_hadamard_transform,
                       constructible, hadamard, hadamard_transform)
from .quantizers import QuantSpec, quantize_act

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class PTQConfig:
    weight_spec: QuantSpec = QuantSpec(fmt="int4")
    act_spec: QuantSpec = QuantSpec(fmt="int4")
    block_size: int = 32                 # b of the online R̃₃
    full_vector_r3: bool = False         # QuaRot reference (R̃₃ = full)
    permutation: str = "massdiff"        # identity|random|absmax|zigzag|massdiff
    rotation: str = "quarot"             # quarot|spinquant|mr|mr_learned|none
    rounding: str = "qronos"             # rtn|gptq|qronos
    cayley_steps: int = 24
    cayley_lr: float = 5e-3
    seed: int = 0


PRESETS: dict[str, PTQConfig] = {
    "perq_star": PTQConfig(permutation="massdiff", rotation="quarot",
                           rounding="qronos"),
    "perq_dagger": PTQConfig(permutation="massdiff", rotation="spinquant",
                             rounding="rtn"),
    "mr_rtn": PTQConfig(permutation="identity", rotation="mr",
                        rounding="rtn"),
    "mr_gptq": PTQConfig(permutation="identity", rotation="mr",
                         rounding="gptq"),
    "mr_qronos": PTQConfig(permutation="identity", rotation="mr",
                           rounding="qronos"),
    "brq_spin": PTQConfig(permutation="identity", rotation="mr_learned",
                          rounding="gptq"),
    "quarot": PTQConfig(permutation="identity", rotation="quarot",
                        rounding="qronos", full_vector_r3=True),
    "rtn_only": PTQConfig(permutation="identity", rotation="none",
                          rounding="rtn"),
}


def preset(name: str, **overrides) -> PTQConfig:
    return dataclasses.replace(PRESETS[name], **overrides)


# ---------------------------------------------------------------------------
# Calibration capture
# ---------------------------------------------------------------------------

class _Capture:
    """Records projection inputs by (tag, occurrence-within-forward)."""

    def __init__(self):
        self.data: dict[tuple[str, int], list[np.ndarray]] = defaultdict(list)
        self._count: dict[str, int] = defaultdict(int)

    def reset_forward(self):
        self._count = defaultdict(int)

    def _record(self, x, tag: str, keep_dims: int = 1):
        occ = self._count[tag]
        self._count[tag] += 1
        arr = np.asarray(x.astype(jnp.float32))
        if keep_dims == 1:
            arr = arr.reshape(-1, arr.shape[-1])
        self.data[(tag, occ)].append(arr)

    def hooks(self) -> dict:
        cap = self

        def act_in(x, tag):
            cap._record(x, tag)
            return x

        def down_proj_fn(h, w):
            cap._record(h, "down")
            return h @ w

        def moe_down_proj_fn(h, w):
            cap._record(h, "moe_down", keep_dims=3)  # [B, E, C, f]
            return jnp.einsum("becf,efd->becd", h, w)

        def ssm_out_proj_fn(y, w):
            cap._record(y, "ssm_out")
            return y @ w

        return {"act_in": act_in, "down_proj_fn": down_proj_fn,
                "moe_down_proj_fn": moe_down_proj_fn,
                "ssm_out_proj_fn": ssm_out_proj_fn}

    def get(self, tag: str, occ: int) -> np.ndarray:
        return np.concatenate(self.data[(tag, occ)], axis=0)

    def get_all(self, tag: str) -> np.ndarray:
        """Concatenate every occurrence (hybrid shared-block calibration)."""
        occs = sorted(o for (t, o) in self.data if t == tag)
        return np.concatenate([self.get(tag, o) for o in occs], axis=0)

    def has(self, tag: str, occ: int = 0) -> bool:
        return (tag, occ) in self.data


# ---------------------------------------------------------------------------
# Rotation / permutation helpers
# ---------------------------------------------------------------------------

def _stream_rotation(d: int, kind: str, b: int, key) -> np.ndarray | None:
    if kind == "none":
        return None
    if kind in ("quarot", "spinquant"):
        if constructible(d):
            return np.asarray(hadamard(d), np.float32) / math.sqrt(d)
        from .hadamard import random_orthogonal
        return np.asarray(random_orthogonal(d, key))
    if kind in ("mr", "mr_learned"):
        return np.asarray(block_hadamard_matrix(d, min(b, d)), np.float32)
    raise ValueError(kind)


def _learn_stream_rotation(r0: np.ndarray, xs: list[np.ndarray],
                           ws: list[np.ndarray], cfg: PTQConfig,
                           block: bool) -> np.ndarray:
    """SpinQuant/BRQ-Spin: Cayley-optimize the stream rotation to minimize
    Σ‖Q_a(xR)(RᵀW) − xW‖² with STE through the quantizers."""
    d = r0.shape[0]
    xs_j = [jnp.asarray(x[: min(len(x), 512)]) for x in xs]
    ws_j = [jnp.asarray(np.asarray(w, np.float32)) for w in ws]

    if block:
        b = min(cfg.block_size, d)
        n = d // b
        r0_small = jnp.asarray(np.asarray(hadamard(b), np.float32)
                               / math.sqrt(b))

        def bapply(x, r_small):
            y = x.reshape(*x.shape[:-1], n, b)
            y = jnp.einsum("...nb,bc->...nc", y, r_small)
            return y.reshape(x.shape)

        def loss_small(r_small):
            total = 0.0
            for x, w in zip(xs_j, ws_j):
                xq = quantize_act(bapply(x, r_small), cfg.act_spec)
                wr = bapply(w.T, r_small).T
                total = total + jnp.mean((xq @ wr - x @ w) ** 2)
            return total

        r_small, _ = learn_rotation(loss_small, b, r0=r0_small,
                                    steps=cfg.cayley_steps, lr=cfg.cayley_lr)
        return np.kron(np.eye(n, dtype=np.float32), np.asarray(r_small))

    def loss(r):
        total = 0.0
        for x, w in zip(xs_j, ws_j):
            xq = quantize_act(x @ r, cfg.act_spec)
            total = total + jnp.mean((xq @ (r.T @ w) - x @ w) ** 2)
        return total

    r, _ = learn_rotation(loss, d, r0=jnp.asarray(r0),
                          steps=cfg.cayley_steps, lr=cfg.cayley_lr)
    return np.asarray(r)


def _ffn_permutation(h_cal: np.ndarray, cfg: PTQConfig, *, d: int,
                     head_dim: int | None = None) -> np.ndarray:
    b = cfg.block_size
    if cfg.full_vector_r3 or b >= d or cfg.permutation == "identity":
        return MD.identity(d)
    if head_dim is None:
        return MD.make_permutation(cfg.permutation, h_cal, b, seed=cfg.seed)
    if b > head_dim or head_dim % b:
        return MD.identity(d)
    perm = np.arange(d, dtype=np.int64)
    for h0 in range(0, d, head_dim):
        sub = MD.make_permutation(cfg.permutation,
                                  h_cal[:, h0:h0 + head_dim], b,
                                  seed=cfg.seed)
        perm[h0:h0 + head_dim] = h0 + sub
    return perm


def _r3_matrix(d: int, cfg: PTQConfig) -> np.ndarray:
    if cfg.full_vector_r3 or cfg.block_size >= d:
        if constructible(d):
            return np.asarray(hadamard(d), np.float32) / math.sqrt(d)
        return np.eye(d, dtype=np.float32)
    return np.asarray(block_hadamard_matrix(d, cfg.block_size), np.float32)


def _apply_r3_online(h: jnp.ndarray, cfg: PTQConfig) -> jnp.ndarray:
    d = h.shape[-1]
    if cfg.full_vector_r3 or cfg.block_size >= d:
        return hadamard_transform(h) if constructible(d) else h
    return block_hadamard_transform(h, cfg.block_size)


def _round_weight(w: np.ndarray, x_fp: np.ndarray | None, cfg: PTQConfig
                  ) -> np.ndarray:
    """Round W [d_in, d_out] given its (transformed) fp input activations."""
    wj = jnp.asarray(np.asarray(w, np.float32))
    if cfg.rounding == "rtn" or x_fp is None or len(x_fp) < 4:
        return np.asarray(RD.rtn(wj, cfg.weight_spec))
    x = jnp.asarray(np.asarray(x_fp, np.float32))
    xq = quantize_act(x, cfg.act_spec) if cfg.act_spec.enabled else x
    hq = RD.hessian_from_activations(xq)
    if cfg.rounding == "gptq":
        return np.asarray(RD.gptq(wj, hq, cfg.weight_spec))
    if cfg.rounding == "qronos":
        c = RD.cross_from_activations(xq, x)
        return np.asarray(RD.qronos(wj, hq, cfg.weight_spec, c_qx=c))
    raise ValueError(cfg.rounding)


# ---------------------------------------------------------------------------
# The pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PTQResult:
    params: Params
    hooks: dict
    config: PTQConfig
    report: dict


def quantize_model(model: Model, params: Params,
                   calib_batches: list[Params], cfg: PTQConfig) -> PTQResult:
    cfg_a = model.cfg
    fam = cfg_a.family
    d = cfg_a.d_model
    n_layers = cfg_a.n_layers
    key = jax.random.PRNGKey(cfg.seed)
    report: dict[str, Any] = {"per_layer": []}

    P = jax.tree.map(lambda a: np.array(a, np.float32), params)
    L = P["layers"]
    A = L.get("attn")          # stacked attention weights [L, ...]
    F = L.get("ffn")           # stacked dense-FFN weights
    MOE = L.get("moe")
    SSM = L.get("ssm")
    SH = P.get("shared_attn")  # hybrid shared block {attn, ffn, norms}

    # ---- 1. fold norm scales (function-preserving) -------------------------
    rmsnorm_stream = cfg_a.norm == "rmsnorm"
    if rmsnorm_stream:
        for i in range(n_layers):
            if fam in ("ssm", "hybrid"):
                g = L["norm"]["scale"][i]
                SSM["in_proj"][i] = g[:, None] * SSM["in_proj"][i]
                L["norm"]["scale"][i] = np.ones_like(g)
            else:
                g = L["attn_norm"]["scale"][i]
                for w in ("wq", "wk", "wv"):
                    A[w][i] = g[:, None] * A[w][i]
                L["attn_norm"]["scale"][i] = np.ones_like(g)
                g = L["ffn_norm"]["scale"][i]
                if cfg_a.uses_moe:
                    MOE["router"][i] = g[:, None] * MOE["router"][i]
                    for w in ("w_gate", "w_up"):
                        MOE[w][i] = g[None, :, None] * MOE[w][i]
                    if "shared_gate" in MOE:
                        for w in ("shared_gate", "shared_up"):
                            MOE[w][i] = g[:, None] * MOE[w][i]
                else:
                    for w in (("w_gate", "w_up") if "w_gate" in F
                              else ("w_up",)):
                        F[w][i] = g[:, None] * F[w][i]
                L["ffn_norm"]["scale"][i] = np.ones_like(g)
        if fam == "hybrid":
            g = SH["attn_norm"]["scale"]
            for w in ("wq", "wk", "wv"):
                SH["attn"][w] = g[:, None] * SH["attn"][w]
            SH["attn_norm"]["scale"] = np.ones_like(g)
            g = SH["ffn_norm"]["scale"]
            for w in ("w_gate", "w_up"):
                SH["ffn"][w] = g[:, None] * SH["ffn"][w]
            SH["ffn_norm"]["scale"] = np.ones_like(g)
        g = P["final_norm"]["scale"]
        P["lm_head"] = g[:, None] * P["lm_head"]
        P["final_norm"]["scale"] = np.ones_like(g)

    # ---- 2. calibrate on the folded model ----------------------------------
    cap = _Capture()
    cap_model = build_model(cfg_a, quant_hooks=cap.hooks())
    folded = jax.tree.map(lambda a: jnp.asarray(a, model.pdt), P)
    for batch in calib_batches:
        cap.reset_forward()
        cap_model.forward(folded, batch, unroll=True)

    # ---- 3. stream rotation R1 + per-head R2 -------------------------------
    use_stream_rot = cfg.rotation != "none" and rmsnorm_stream
    r1 = _stream_rotation(d, cfg.rotation, cfg.block_size, key) \
        if use_stream_rot else None

    if r1 is not None and cfg.rotation in ("spinquant", "mr_learned"):
        if fam in ("ssm", "hybrid"):
            tag, wsrc = "ssm_in", SSM["in_proj"]
        else:
            tag, wsrc = "qkv", A["wq"]
        xs, ws = [], []
        for i in range(min(n_layers, 4)):
            if cap.has(tag, i):
                xs.append(cap.get(tag, i))
                ws.append(wsrc[i])
        if xs:
            r1 = _learn_stream_rotation(
                r1, xs, ws, cfg, block=(cfg.rotation == "mr_learned"))
    report["r1"] = None if r1 is None else cfg.rotation

    dh = cfg_a.head_dim
    r2 = None
    if r1 is not None and cfg_a.n_heads and constructible(dh):
        r2 = np.asarray(hadamard(dh), np.float32) / math.sqrt(dh)

    def rotate_attn(tgt):
        """tgt: dict view of one attention block's weights."""
        for w in ("wq", "wk", "wv"):
            tgt[w] = r1.T @ tgt[w]
        tgt["wo"] = tgt["wo"] @ r1
        if r2 is not None:
            wv, wo = merge_head_rotation(
                jnp.asarray(tgt["wv"]), jnp.asarray(tgt["wo"]),
                jnp.asarray(r2), cfg_a.n_kv_heads, cfg_a.n_heads)
            tgt["wv"], tgt["wo"] = np.asarray(wv), np.asarray(wo)
            if "bv" in tgt:
                bv = tgt["bv"].reshape(cfg_a.n_kv_heads, dh)
                tgt["bv"] = np.asarray(bv @ r2).reshape(-1)

    if r1 is not None:
        for i in range(n_layers):
            if fam in ("ssm", "hybrid"):
                SSM["in_proj"][i] = r1.T @ SSM["in_proj"][i]
                SSM["out_proj"][i] = SSM["out_proj"][i] @ r1
            else:
                view = {w: A[w][i] for w in ("wq", "wk", "wv", "wo")}
                if "bv" in A:
                    view["bv"] = A["bv"][i]
                rotate_attn(view)
                for w, v in view.items():
                    A[w][i] = v
                if cfg_a.uses_moe:
                    MOE["router"][i] = r1.T @ MOE["router"][i]
                    for w in ("w_gate", "w_up"):
                        # rotate the d axis of [E, d, f]: R1ᵀ W_e per expert
                        MOE[w][i] = np.einsum("ad,edf->eaf", r1.T, MOE[w][i])
                    MOE["w_down"][i] = np.einsum("efd,dc->efc",
                                                 MOE["w_down"][i], r1)
                    if "shared_gate" in MOE:
                        for w in ("shared_gate", "shared_up"):
                            MOE[w][i] = r1.T @ MOE[w][i]
                        MOE["shared_down"][i] = MOE["shared_down"][i] @ r1
                else:
                    for w in (("w_gate", "w_up") if "w_gate" in F
                              else ("w_up",)):
                        F[w][i] = r1.T @ F[w][i]
                    F["w_down"][i] = F["w_down"][i] @ r1
        if fam == "hybrid":
            view = dict(SH["attn"])
            rotate_attn(view)
            SH["attn"].update(view)
            for w in ("w_gate", "w_up"):
                SH["ffn"][w] = r1.T @ SH["ffn"][w]
            SH["ffn"]["w_down"] = SH["ffn"]["w_down"] @ r1
        if "embed" in P:
            P["embed"] = P["embed"] @ r1
        if "frontend_proj" in P:
            P["frontend_proj"] = P["frontend_proj"] @ r1
        P["lm_head"] = r1.T @ P["lm_head"]

    # transformed-activation helpers (captures are post-fold, pre-rotation)
    def tx(x):
        return x if r1 is None else x @ r1

    def tx_wo(x):
        if r2 is None:
            return x
        xx = x.reshape(len(x), -1, dh)
        return (xx @ r2).reshape(x.shape)

    # ---- 4+5. permutation merge + rounding ---------------------------------
    def do_attn(tgt, x_qkv, x_wo):
        for w in ("wq", "wk", "wv"):
            tgt[w] = _round_weight(tgt[w], tx(x_qkv), cfg)
        tgt["wo"] = _round_weight(tgt["wo"], tx_wo(x_wo), cfg)

    def do_ffn(tgt, x_ffn, h_down, has_gate=True):
        dff = tgt["w_down"].shape[0]
        perm = _ffn_permutation(h_down, cfg, d=dff)
        r3 = _r3_matrix(dff, cfg)
        if has_gate:
            tgt["w_gate"] = np.asarray(
                permute_producer(jnp.asarray(tgt["w_gate"]), perm))
        tgt["w_up"] = np.asarray(
            permute_producer(jnp.asarray(tgt["w_up"]), perm))
        tgt["w_down"] = r3.T @ np.asarray(
            permute_consumer(jnp.asarray(tgt["w_down"]), perm))
        x_t = tx(x_ffn)
        if has_gate:
            tgt["w_gate"] = _round_weight(tgt["w_gate"], x_t, cfg)
        tgt["w_up"] = _round_weight(tgt["w_up"], x_t, cfg)
        h_t = h_down[:, perm] @ r3
        tgt["w_down"] = _round_weight(tgt["w_down"], h_t, cfg)
        mb = min(cfg.block_size, dff)
        mass = np.abs(h_down).mean(0)
        report["per_layer"].append({
            "max_block_l1_before": float(mass.reshape(-1, mb).sum(-1).max()),
            "max_block_l1_after": float(
                mass[perm].reshape(-1, mb).sum(-1).max()),
        })
        return perm

    if fam in ("dense", "vlm", "encoder"):
        has_gate = "w_gate" in F
        for i in range(n_layers):
            view = {w: A[w][i] for w in ("wq", "wk", "wv", "wo")}
            do_attn(view, cap.get("qkv", i), cap.get("wo", i))
            for w, v in view.items():
                A[w][i] = v
            fview = {w: F[w][i]
                     for w in (("w_gate", "w_up", "w_down") if has_gate
                               else ("w_up", "w_down"))}
            do_ffn(fview, cap.get("ffn", i), cap.get("down", i),
                   has_gate=has_gate)
            for w, v in fview.items():
                F[w][i] = v
    elif fam == "moe":
        e = cfg_a.n_experts
        for i in range(n_layers):
            view = {w: A[w][i] for w in ("wq", "wk", "wv", "wo")}
            do_attn(view, cap.get("qkv", i), cap.get("wo", i))
            for w, v in view.items():
                A[w][i] = v
            x_ffn = cap.get("ffn", i)
            h_all = cap.get("moe_down", i)          # [N, E, C, f]
            x_exp = cap.get("expert_in", i).reshape(
                h_all.shape[0], e, -1, d)            # [N, E, C, d]
            for ex in range(e):
                h_e = h_all[:, ex].reshape(-1, h_all.shape[-1])
                live = np.abs(h_e).sum(-1) > 0
                h_live = h_e[live] if live.any() else h_e
                x_live = tx(x_exp[:, ex].reshape(-1, d)[live]) \
                    if live.any() else None
                ev = {"w_gate": MOE["w_gate"][i, ex],
                      "w_up": MOE["w_up"][i, ex],
                      "w_down": MOE["w_down"][i, ex]}
                do_ffn(ev, x_live if x_live is not None
                       else np.zeros((2, d), np.float32), h_live)
                MOE["w_gate"][i, ex] = ev["w_gate"]
                MOE["w_up"][i, ex] = ev["w_up"]
                MOE["w_down"][i, ex] = ev["w_down"]
            if "shared_gate" in MOE:
                # captured at the shared expert's down projection ("down"
                # tag: only the shared path uses that hook in MoE layers)
                sh_h = cap.get("down", i)
                sv = {"w_gate": MOE["shared_gate"][i],
                      "w_up": MOE["shared_up"][i],
                      "w_down": MOE["shared_down"][i]}
                do_ffn(sv, x_ffn, sh_h)
                MOE["shared_gate"][i] = sv["w_gate"]
                MOE["shared_up"][i] = sv["w_up"]
                MOE["shared_down"][i] = sv["w_down"]
    elif fam in ("ssm", "hybrid"):
        for i in range(n_layers):
            x_in = tx(cap.get("ssm_in", i))
            SSM["in_proj"][i] = _round_weight(SSM["in_proj"][i], x_in, cfg)
            y = cap.get("ssm_out", i)
            d_inner = y.shape[-1]
            perm = _ffn_permutation(y, cfg, d=d_inner,
                                    head_dim=cfg_a.ssm_head_dim)
            r3 = _r3_matrix(d_inner, cfg)
            _permute_ssm_channels(P, i, perm, cfg_a)
            wd = r3.T @ SSM["out_proj"][i][perm, :]
            y_t = y[:, perm] @ r3
            SSM["out_proj"][i] = _round_weight(wd, y_t, cfg)
            mb = min(cfg.block_size, d_inner)
            mass = np.abs(y).mean(0)
            report["per_layer"].append({
                "max_block_l1_before": float(
                    mass.reshape(-1, mb).sum(-1).max()),
                "max_block_l1_after": float(
                    mass[perm].reshape(-1, mb).sum(-1).max())})
        if fam == "hybrid":
            view = dict(SH["attn"])
            do_attn(view, cap.get_all("qkv"), cap.get_all("wo"))
            SH["attn"].update(view)
            fview = dict(SH["ffn"])
            do_ffn(fview, cap.get_all("ffn"), cap.get_all("down"))
            SH["ffn"].update(fview)

    # ---- runtime hooks ------------------------------------------------------
    act_spec = cfg.act_spec

    def act_in(x, tag):
        return quantize_act(x, act_spec)

    def down_proj_fn(h, w):
        return quantize_act(_apply_r3_online(h, cfg), act_spec) @ w

    def moe_down_proj_fn(h, w):
        hq = quantize_act(_apply_r3_online(h, cfg), act_spec)
        return jnp.einsum("becf,efd->becd", hq, w)

    def ssm_out_proj_fn(y, w):
        return quantize_act(_apply_r3_online(y, cfg), act_spec) @ w

    hooks = {"act_in": act_in, "down_proj_fn": down_proj_fn,
             "moe_down_proj_fn": moe_down_proj_fn,
             "ssm_out_proj_fn": ssm_out_proj_fn}

    qparams = jax.tree.map(lambda a: jnp.asarray(a, model.pdt), P)
    return PTQResult(params=qparams, hooks=hooks, config=cfg, report=report)


def _permute_ssm_channels(P: Params, i: int, perm: np.ndarray, cfg_a):
    """Permute the Mamba2 inner channels jointly across (z, x, conv, norm)
    so the out-proj permutation is absorbed. Head-preserving perms only:
    conv is depthwise and SSD is elementwise in the within-head channel, so
    the region is permutation-equivariant (DESIGN.md §Arch-applicability)."""
    d_inner = len(perm)
    SSM = P["layers"]["ssm"]
    in_proj = SSM["in_proj"][i]
    z_cols = in_proj[:, :d_inner][:, perm]
    x_cols = in_proj[:, d_inner:2 * d_inner][:, perm]
    rest = in_proj[:, 2 * d_inner:]
    SSM["in_proj"][i] = np.concatenate([z_cols, x_cols, rest], axis=1)
    conv_w = np.array(SSM["conv_w"][i])
    conv_b = np.array(SSM["conv_b"][i])
    conv_w[:, :d_inner] = conv_w[:, :d_inner][:, perm]
    conv_b[:d_inner] = conv_b[:d_inner][perm]
    SSM["conv_w"][i] = conv_w
    SSM["conv_b"][i] = conv_b
    SSM["norm_scale"][i] = SSM["norm_scale"][i][perm]


def build_quantized_model(model: Model, result: PTQResult) -> Model:
    return build_model(model.cfg, quant_hooks=result.hooks)
