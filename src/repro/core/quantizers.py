"""Quantizers for the data formats in the paper (Appendix B).

  * INT-q   — Eq. (4): symmetric per-channel weights (MSE-searched scale),
              asymmetric dynamic per-token activations.
  * FP4     — Eq. (5): OCP e2m1 element format, symmetric; per-channel
              MSE-searched weight scale, per-token absmax activation scale.
  * MXFP4   — FP4 elements with a shared power-of-2 scale per group of 32
              (OCP microscaling), for weights and activations.

All quantizers are fake-quant (quantize→dequantize) pure-jnp functions so they
compose with jit/grad (via the STE in `ste_round`). Integer *storage* paths
(packed int4) live in `repro.kernels.int4_matmul`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp

__all__ = [
    "ste_round",
    "int_quantize",
    "fp4_quantize",
    "int_weight_scales_mse",
    "fp4_weight_scales_mse",
    "quantize_weight",
    "quantize_act",
    "QuantSpec",
    "FP4_VALUES",
]

# e2m1 representable magnitudes (OCP MX spec: e=2, m=1, no inf/nan).
FP4_VALUES = jnp.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], dtype=jnp.float32)
FP4_MAX = 6.0


@jax.custom_vjp
def ste_round(x):
    """Round-to-nearest(-even ties) with a straight-through gradient."""
    return jnp.round(x)


def _ste_fwd(x):
    return jnp.round(x), None


def _ste_bwd(_, g):
    return (g,)


ste_round.defvjp(_ste_fwd, _ste_bwd)


# ---------------------------------------------------------------------------
# Element quantizers
# ---------------------------------------------------------------------------

def int_quantize(x: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
                 bits: int, *, signed: bool = True) -> jnp.ndarray:
    """Integer fake-quant per Eq. (4): s·clip(⌊x/s⌉ − z, min A, max A) + s·z.

    `scale`/`zero` broadcast against x. For the symmetric weight quantizer
    zero = 0 and A = [−2^{q−1}+1, 2^{q−1}−1]; for the asymmetric activation
    quantizer A = [0, 2^q − 1] with z = round(min(x)/s).
    """
    if signed:
        lo, hi = -(2 ** (bits - 1)) + 1, 2 ** (bits - 1) - 1
    else:
        lo, hi = 0, 2 ** bits - 1
    scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(ste_round(x / scale) - zero, lo, hi)
    return scale * (q + zero)


def fp4_quantize(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """FP4 (e2m1) fake-quant per Eq. (5), symmetric (z = 0).

    x/s is rounded to the nearest representable e2m1 value via exponent/
    mantissa arithmetic (matches a LUT nearest-neighbor over FP4_VALUES with
    round-half-to-even on the mantissa), then clipped to ±6 and rescaled.
    """
    scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    v = x / scale
    a = jnp.abs(v)
    # Exponent of the fp4 binade; subnormals (a < 1) use the 0.5 step binade.
    e = jnp.floor(jnp.log2(jnp.maximum(a, 1e-30)))
    e = jnp.clip(e, 0.0, 2.0)  # binades: [1,2), [2,4), [4,8); below 1 → step 0.5
    step = jnp.where(a < 1.0, 0.5, 2.0 ** (e - 1.0))  # m=1 → 2 mantissa steps/binade
    q = ste_round(a / step) * step
    q = jnp.minimum(q, FP4_MAX)
    return scale * jnp.sign(v) * q


# ---------------------------------------------------------------------------
# Scale search (weights) — linear MSE search as in Appendix B
# ---------------------------------------------------------------------------

def _mse_scale_search(w: jnp.ndarray, axis: int, qfun, maxval: float,
                      n_grid: int = 80, shrink: float = 0.2) -> jnp.ndarray:
    """Per-channel linear search s = r·absmax/maxval, r ∈ [shrink, 1]."""
    absmax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    absmax = jnp.maximum(absmax, jnp.finfo(jnp.float32).tiny)
    ratios = jnp.linspace(shrink, 1.0, n_grid)

    def mse_for(r):
        s = r * absmax / maxval
        err = qfun(w, s) - w
        return jnp.sum(err * err, axis=axis, keepdims=True)

    mses = jax.vmap(mse_for)(ratios)  # [n_grid, ...]
    best = jnp.argmin(mses, axis=0)
    best_r = jnp.take(ratios, best)
    return best_r * absmax / maxval


def int_weight_scales_mse(w: jnp.ndarray, bits: int, *, axis: int = 0,
                          n_grid: int = 80) -> jnp.ndarray:
    """Symmetric per-channel INT scale via MSE linear search (z = 0)."""
    maxval = 2 ** (bits - 1) - 1

    def qfun(x, s):
        return int_quantize(x, s, 0.0, bits, signed=True)

    return _mse_scale_search(w, axis, qfun, maxval, n_grid=n_grid)


def fp4_weight_scales_mse(w: jnp.ndarray, *, axis: int = 0,
                          n_grid: int = 80) -> jnp.ndarray:
    """Symmetric per-channel FP4 scale via MSE linear search."""
    return _mse_scale_search(w, axis, fp4_quantize, FP4_MAX, n_grid=n_grid)


# ---------------------------------------------------------------------------
# MX grouping
# ---------------------------------------------------------------------------

def _mx_shared_scale(x: jnp.ndarray, group: int, maxval_log2: float) -> jnp.ndarray:
    """Shared power-of-2 scale per `group` along the last axis (E8M0 style):
    2^(⌊log2 absmax⌋ − emax_elem), emax_elem = log2(largest element binade)."""
    g = x.reshape(*x.shape[:-1], x.shape[-1] // group, group)
    absmax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    absmax = jnp.maximum(absmax, jnp.finfo(jnp.float32).tiny)
    e = jnp.floor(jnp.log2(absmax)) - maxval_log2
    return 2.0 ** e, g


def mxfp4_quantize(x: jnp.ndarray, *, group: int = 32) -> jnp.ndarray:
    """MXFP4 fake-quant: e2m1 elements + shared pow-2 scale per 32 elements."""
    if x.shape[-1] % group:
        raise ValueError(f"last dim {x.shape[-1]} not divisible by group {group}")
    s, g = _mx_shared_scale(x, group, maxval_log2=2.0)  # fp4 emax = 2 (val 4; 6 = 1.5·4)
    q = fp4_quantize(g, s)
    return q.reshape(x.shape)


# ---------------------------------------------------------------------------
# Unified spec + entry points
# ---------------------------------------------------------------------------

Format = Literal["int4", "int8", "fp4", "mxfp4", "none"]


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """What to quantize and how — one per tensor class (weights / acts)."""
    fmt: Format = "int4"
    bits: int = 4
    mx_group: int = 32
    scale_grid: int = 80

    @property
    def enabled(self) -> bool:
        return self.fmt != "none"


def quantize_weight(w: jnp.ndarray, spec: QuantSpec, *, axis: int = 0,
                    precomputed_scale: jnp.ndarray | None = None) -> jnp.ndarray:
    """Fake-quantize a weight matrix per the spec (channel axis = `axis`,
    i.e. scales are per-output-channel when axis is the input dim)."""
    if not spec.enabled:
        return w
    if spec.fmt in ("int4", "int8"):
        bits = 4 if spec.fmt == "int4" else 8
        s = precomputed_scale if precomputed_scale is not None else \
            int_weight_scales_mse(w, bits, axis=axis, n_grid=spec.scale_grid)
        return int_quantize(w, s, 0.0, bits, signed=True)
    if spec.fmt == "fp4":
        s = precomputed_scale if precomputed_scale is not None else \
            fp4_weight_scales_mse(w, axis=axis, n_grid=spec.scale_grid)
        return fp4_quantize(w, s)
    if spec.fmt == "mxfp4":
        # MX scales are data-derived pow-2 per group of the *input-dim* axis.
        if axis != 0:
            w = jnp.swapaxes(w, axis, 0)
        q = mxfp4_quantize(jnp.swapaxes(w, 0, -1), group=spec.mx_group)
        q = jnp.swapaxes(q, 0, -1)
        if axis != 0:
            q = jnp.swapaxes(q, axis, 0)
        return q
    raise ValueError(spec.fmt)


def quantize_act(x: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Dynamic activation fake-quant over the last (feature) axis.

    int4/int8 → asymmetric per-token (Eq. 4 with dynamic z, s);
    fp4       → symmetric per-token absmax scale;
    mxfp4     → shared pow-2 scale per group of 32.
    """
    if not spec.enabled:
        return x
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if spec.fmt in ("int4", "int8"):
        bits = 4 if spec.fmt == "int4" else 8
        mn = jnp.min(xf, axis=-1, keepdims=True)
        mx = jnp.max(xf, axis=-1, keepdims=True)
        s = jnp.maximum((mx - mn) / (2 ** bits - 1), jnp.finfo(jnp.float32).tiny)
        z = jnp.round(mn / s)
        q = jnp.clip(ste_round(xf / s) - z, 0, 2 ** bits - 1)
        out = s * (q + z)
    elif spec.fmt == "fp4":
        s = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / (2 ** (4 - 1) - 1)
        out = fp4_quantize(xf, s)
    elif spec.fmt == "mxfp4":
        out = mxfp4_quantize(xf, group=spec.mx_group)
    else:
        raise ValueError(spec.fmt)
    return out.astype(dtype)
