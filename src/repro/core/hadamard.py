"""Hadamard rotation construction and application.

Implements:
  * Sylvester construction for power-of-2 orders.
  * Paley I  (q prime, q ≡ 3 mod 4  → order q+1).
  * Paley II (q prime, q ≡ 1 mod 4  → order 2(q+1)).
  * General `hadamard(n)` for n = 2^a · m via Kronecker(Sylvester, Paley-base),
    covering every activation dimension in the assigned architectures
    (e.g. 14336 = 2^9·28 via Paley-II(13); 19200 = 2^6·300 via Paley-II(149)).
  * Fast Walsh-Hadamard transform (power-of-2) as a reshape butterfly.
  * Non-power-of-2 transform per Appendix A.1: k' radix-2 butterfly stages +
    2^{k'} independent 4t-dimensional base rotations (H_d = H_{2^{k'}} ⊗ H_{4t}).
  * Block Hadamard application (I_n ⊗ H_b) without materializing the d×d matrix.
  * Op-count models reproducing paper Tables 3 and 4.

All rotations here are *normalized* (‖R_i‖₂ = 1) unless stated otherwise, so
they are orthonormal and ‖R_i‖∞ = 1/√k as used throughout the paper's analysis.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "sylvester",
    "paley1",
    "paley2",
    "hadamard",
    "is_hadamard",
    "random_orthogonal",
    "rotation_matrix",
    "fwht",
    "hadamard_transform",
    "block_hadamard_transform",
    "block_hadamard_matrix",
    "decompose_dim",
    "ops_dense_matmul",
    "ops_butterfly_matmul",
    "ops_optimized",
    "ops_block",
    "ops_full_vector",
]


# ---------------------------------------------------------------------------
# Construction (numpy; these run at trace/calibration time, never per-step)
# ---------------------------------------------------------------------------

def _is_prime(q: int) -> bool:
    if q < 2:
        return False
    if q % 2 == 0:
        return q == 2
    i = 3
    while i * i <= q:
        if q % i == 0:
            return False
        i += 2
    return True


@functools.lru_cache(maxsize=None)
def sylvester(n: int) -> np.ndarray:
    """Sylvester Hadamard matrix of power-of-2 order n (entries ±1)."""
    if n & (n - 1) or n < 1:
        raise ValueError(f"Sylvester order must be a power of 2, got {n}")
    H = np.array([[1]], dtype=np.int8)
    while H.shape[0] < n:
        H = np.block([[H, H], [H, -H]])
    return H.astype(np.int8)


def _jacobsthal(q: int) -> np.ndarray:
    """Jacobsthal matrix Q[i,j] = χ(j - i) over GF(q), χ the Legendre symbol."""
    residues = np.zeros(q, dtype=np.int8)
    squares = set((i * i) % q for i in range(1, q))
    for a in range(1, q):
        residues[a] = 1 if a in squares else -1
    idx = (np.arange(q)[None, :] - np.arange(q)[:, None]) % q
    return residues[idx]


@functools.lru_cache(maxsize=None)
def paley1(q: int) -> np.ndarray:
    """Paley construction I: Hadamard of order q+1 for prime q ≡ 3 (mod 4)."""
    if not _is_prime(q) or q % 4 != 3:
        raise ValueError(f"Paley I needs prime q ≡ 3 mod 4, got {q}")
    n = q + 1
    Q = _jacobsthal(q)
    S = np.zeros((n, n), dtype=np.int8)
    S[0, 1:] = 1
    S[1:, 0] = -1
    S[1:, 1:] = Q
    H = S + np.eye(n, dtype=np.int8)
    return H.astype(np.int8)


@functools.lru_cache(maxsize=None)
def paley2(q: int) -> np.ndarray:
    """Paley construction II: Hadamard of order 2(q+1) for prime q ≡ 1 (mod 4)."""
    if not _is_prime(q) or q % 4 != 1:
        raise ValueError(f"Paley II needs prime q ≡ 1 mod 4, got {q}")
    n = q + 1
    Q = _jacobsthal(q)
    S = np.zeros((n, n), dtype=np.int8)
    S[0, 1:] = 1
    S[1:, 0] = 1
    S[1:, 1:] = Q
    # Substitute: 0 → [[1,-1],[-1,-1]], ±1 → ±[[1,1],[1,-1]].
    # For Paley-II S the zeros sit exactly on the diagonal.
    pos = np.array([[1, 1], [1, -1]], dtype=np.int8)
    zer = np.array([[1, -1], [-1, -1]], dtype=np.int8)
    H = np.kron(S, pos)
    for i in range(n):
        H[2 * i : 2 * i + 2, 2 * i : 2 * i + 2] = zer
    return H.astype(np.int8)


def is_hadamard(H: np.ndarray) -> bool:
    n = H.shape[0]
    if H.shape != (n, n) or not np.all(np.abs(H) == 1):
        return False
    G = H.astype(np.int64) @ H.astype(np.int64).T
    return bool(np.array_equal(G, n * np.eye(n, dtype=np.int64)))


@functools.lru_cache(maxsize=None)
def decompose_dim(d: int) -> tuple[int, int]:
    """Split d = k · t with t the odd part and k the power-of-2 part."""
    t = d
    while t % 2 == 0:
        t //= 2
    return d // t, t


@functools.lru_cache(maxsize=None)
def _base_order_for(t: int, max_pow: int) -> tuple[np.ndarray, int] | None:
    """Find a Paley-constructible Hadamard of order t·2^s for the smallest s ≤ max_pow."""
    for s in range(0, max_pow + 1):
        order = t << s
        if order == 1:
            return sylvester(1), 0
        if order % 4 != 0 and order not in (1, 2):
            continue
        q = order - 1
        if _is_prime(q) and q % 4 == 3:
            return paley1(q), s
        if order % 2 == 0:
            q = order // 2 - 1
            if _is_prime(q) and q % 4 == 1:
                return paley2(q), s
    return None


@functools.lru_cache(maxsize=None)
def hadamard(n: int) -> np.ndarray:
    """Hadamard matrix of order n (entries ±1). Raises ValueError when the
    Sylvester/Paley toolbox cannot construct it (callers may fall back to
    `random_orthogonal`)."""
    if n < 1:
        raise ValueError("order must be positive")
    if n == 1:
        return np.array([[1]], dtype=np.int8)
    if n == 2:
        return np.array([[1, 1], [1, -1]], dtype=np.int8)
    if n % 4 != 0:
        raise ValueError(f"No Hadamard matrix of order {n} (n % 4 != 0)")
    k, t = decompose_dim(n)
    if t == 1:
        return sylvester(n)
    a = int(math.log2(k))
    found = _base_order_for(t, a)
    if found is None:
        raise ValueError(f"Cannot construct Hadamard of order {n} = 2^{a}·{t}")
    base, s = found
    rem = a - s
    H = np.kron(sylvester(1 << rem), base).astype(np.int8)
    return H


def constructible(n: int) -> bool:
    """True when `hadamard(n)` can build the order without materializing it."""
    if n in (1, 2):
        return True
    if n < 1 or n % 4 != 0:
        return False
    k, t = decompose_dim(n)
    if t == 1:
        return True
    return _base_order_for(t, int(math.log2(k))) is not None


def random_orthogonal(n: int, key: jax.Array) -> jnp.ndarray:
    """Haar-random orthogonal matrix (QuaRot-style fallback rotation)."""
    g = jax.random.normal(key, (n, n), dtype=jnp.float32)
    q, r = jnp.linalg.qr(g)
    return q * jnp.sign(jnp.diagonal(r))[None, :]


def rotation_matrix(n: int, *, key: jax.Array | None = None,
                    dtype=jnp.float32) -> jnp.ndarray:
    """Normalized rotation of order n: Hadamard when constructible, else a
    Haar-random orthogonal fallback (requires `key`)."""
    try:
        H = hadamard(n).astype(np.float32) / np.sqrt(n)
        return jnp.asarray(H, dtype=dtype)
    except ValueError:
        if key is None:
            raise
        return random_orthogonal(n, key).astype(dtype)


# ---------------------------------------------------------------------------
# Application (jnp; traced into models and kernels)
# ---------------------------------------------------------------------------

def fwht(x: jnp.ndarray, *, normalize: bool = True) -> jnp.ndarray:
    """Fast Walsh-Hadamard transform over the last axis (power-of-2 length).

    Matches `x @ sylvester(d)` (and /√d when normalized). Implemented as a
    reshape butterfly — log2(d) stages of adds/subs.
    """
    shape = x.shape
    d = shape[-1]
    if d & (d - 1):
        raise ValueError(f"fwht needs power-of-2 length, got {d}")
    y = x.reshape(-1, d)
    h = 1
    while h < d:
        y = y.reshape(-1, d // (2 * h), 2, h)
        a, b = y[:, :, 0, :], y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    y = y.reshape(shape)
    if normalize:
        y = y * jnp.asarray(1.0 / math.sqrt(d), x.dtype)
    return y


def hadamard_transform(x: jnp.ndarray, *, normalize: bool = True) -> jnp.ndarray:
    """Full-vector Hadamard rotation over the last axis for any constructible d.

    Power-of-2 d uses the FWHT butterfly. Non-power-of-2 d = 2^{k'}·(base) uses
    the Appendix-A.1 structure: butterfly stages across the outer 2^{k'} axis +
    dense base-order rotations on the inner axis (H_d = H_{2^{k'}} ⊗ H_base).
    """
    d = x.shape[-1]
    if d & (d - 1) == 0:
        return fwht(x, normalize=normalize)
    k, t = decompose_dim(d)
    a = int(math.log2(k))
    found = _base_order_for(t, a)
    if found is None:
        raise ValueError(f"No Hadamard construction for d={d}")
    base, s = found
    base_order = t << s
    outer = d // base_order
    B = jnp.asarray(base.astype(np.float32), x.dtype)
    shape = x.shape
    y = x.reshape(-1, outer, base_order)
    # Inner dense base rotation (the 4t-dim sub-rotation of Fig. 8).
    y = jnp.einsum("rob,bc->roc", y, B)
    # Outer radix-2 butterflies (k' stages) via FWHT on the outer axis.
    y = jnp.swapaxes(y, -1, -2)  # (-1, base, outer)
    y = fwht(y, normalize=False)
    y = jnp.swapaxes(y, -1, -2).reshape(shape)
    if normalize:
        y = y * jnp.asarray(1.0 / math.sqrt(d), x.dtype)
    return y


def block_hadamard_matrix(d: int, b: int, dtype=jnp.float32) -> jnp.ndarray:
    """Materialized I_n ⊗ H_b (normalized). Test/reference use only."""
    if d % b:
        raise ValueError(f"d={d} not divisible by b={b}")
    Hb = hadamard(b).astype(np.float32) / np.sqrt(b)
    return jnp.asarray(np.kron(np.eye(d // b, dtype=np.float32), Hb), dtype=dtype)


def block_hadamard_transform(x: jnp.ndarray, b: int, *,
                             normalize: bool = True) -> jnp.ndarray:
    """Apply the block rotation X·(I_n ⊗ H_b) over the last axis.

    Pure-jnp reference implementation (the Pallas kernel in
    `repro.kernels.block_hadamard` is the TPU production path).
    """
    d = x.shape[-1]
    if d % b:
        raise ValueError(f"d={d} not divisible by b={b}")
    if b & (b - 1) == 0:
        y = x.reshape(*x.shape[:-1], d // b, b)
        y = fwht(y, normalize=normalize)
        return y.reshape(x.shape)
    Hb = jnp.asarray(hadamard(b).astype(np.float32), x.dtype)
    if normalize:
        Hb = Hb * jnp.asarray(1.0 / math.sqrt(b), x.dtype)
    y = x.reshape(*x.shape[:-1], d // b, b)
    y = jnp.einsum("...nb,bc->...nc", y, Hb)
    return y.reshape(x.shape)


# ---------------------------------------------------------------------------
# Op-count models (paper Appendix A, Tables 3 & 4)
# ---------------------------------------------------------------------------

def _kprime_t(d: int) -> tuple[int, int]:
    """k' and t such that d = 2^{k'} · 4t with t the odd part (App. A.1)."""
    k, t = decompose_dim(d)
    if t == 1:
        return int(math.log2(d)), 0
    kprime = int(math.log2(k)) - 2
    return kprime, t


def ops_dense_matmul(d: int) -> int:
    """Dense rotation matmul: d² multiply-accumulates."""
    return d * d


def ops_butterfly_matmul(d: int) -> int:
    """Butterfly stages + dense 4t-dim base matmuls (Dao 2023 style):
    d·k' add/subs + 2^{k'} · 4t·(4t−1) base ops."""
    kprime, t = _kprime_t(d)
    if t == 0:
        return d * int(math.log2(d))
    return d * kprime + (1 << kprime) * (4 * t) * (4 * t - 1)


def ops_optimized(d: int) -> int:
    """The paper's optimized non-power-of-2 rotation: d·(k' + t + 2) ops.
    Power-of-2 dims reduce to the plain butterfly d·log2(d)."""
    kprime, t = _kprime_t(d)
    if t == 0:
        return d * int(math.log2(d))
    return d * (kprime + t + 2)


def ops_block(d: int, b: int) -> int:
    """Block Hadamard rotation: d·log2(b) add/subs (power-of-2 b)."""
    if b & (b - 1):
        raise ValueError("block size must be a power of 2 for the FWHT count")
    return d * int(math.log2(b))


def ops_full_vector(d: int) -> int:
    """Minimum ops for a full-vector rotation = the optimized count."""
    return ops_optimized(d)
