"""Stage-2 rounding algorithms: RTN, GPTQ (OPTQ), Qronos.

Conventions
-----------
Layers compute ``y = x @ W`` with ``W: [d_in, d_out]``. The Hessian is
``H = XᵀX : [d_in, d_in]`` accumulated over calibration tokens. GPTQ/Qronos
quantize the d_in rows of W sequentially, diffusing the rounding error into
not-yet-quantized rows via the upper Cholesky factor of H⁻¹ (exact OPTQ
recursion). Per Appendix B we

  * damp GPTQ with λ = damp_frac · mean(diag H) (1%),
  * damp Qronos with λ = 1e-3 · σ₁(H) (largest singular value),
  * quantize rows in descending order of diag(H) ("act order"),
  * compute weight scales from the original full-precision W (per output
    channel for INT/FP4; per 32-row group for MXFP4) before the loop.

Qronos ("correct the past by shaping the future", Zhang et al. 2026): when
the layer inputs themselves are quantized (X̃ ≠ X), first re-fit the weights
against the quantized inputs — W ← (X̃ᵀX̃ + λI)⁻¹ X̃ᵀX · W — which corrects the
error already committed upstream; then run the GPTQ recursion with H = X̃ᵀX̃.
With X̃ = X the re-fit is the identity and Qronos reduces to GPTQ exactly.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .quantizers import (QuantSpec, fp4_quantize, fp4_weight_scales_mse,
                         int_quantize, int_weight_scales_mse)

__all__ = [
    "hessian_from_activations",
    "cross_from_activations",
    "row_scales",
    "rtn",
    "gptq",
    "qronos",
]


def hessian_from_activations(x: jnp.ndarray) -> jnp.ndarray:
    """H = XᵀX in float32; x is [tokens, d_in] (flatten batch/seq first)."""
    x = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    return x.T @ x


def cross_from_activations(x_q: jnp.ndarray, x_fp: jnp.ndarray) -> jnp.ndarray:
    """C = X̃ᵀX in float32 for the Qronos re-fit."""
    x_q = x_q.reshape(-1, x_q.shape[-1]).astype(jnp.float32)
    x_fp = x_fp.reshape(-1, x_fp.shape[-1]).astype(jnp.float32)
    return x_q.T @ x_fp


def row_scales(w: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Scale for each row of W (broadcastable to W): INT/FP4 → per output
    channel [1, d_out]; MXFP4 → per (32-row group × output channel) [d_in, d_out]
    with power-of-2 shared scales (static-group approximation: scales fixed
    from the original W before error diffusion)."""
    if spec.fmt in ("int4", "int8"):
        bits = 4 if spec.fmt == "int4" else 8
        return int_weight_scales_mse(w, bits, axis=0, n_grid=spec.scale_grid)
    if spec.fmt == "fp4":
        return fp4_weight_scales_mse(w, axis=0, n_grid=spec.scale_grid)
    if spec.fmt == "mxfp4":
        d_in, d_out = w.shape
        g = spec.mx_group
        if d_in % g:
            raise ValueError(f"d_in={d_in} not divisible by MX group {g}")
        wg = w.reshape(d_in // g, g, d_out)
        absmax = jnp.maximum(jnp.max(jnp.abs(wg), axis=1, keepdims=True),
                             jnp.finfo(jnp.float32).tiny)
        e = jnp.floor(jnp.log2(absmax)) - 2.0  # fp4 emax = 2
        s = jnp.broadcast_to(2.0 ** e, wg.shape).reshape(d_in, d_out)
        return s
    raise ValueError(spec.fmt)


def _quantize_rows(w: jnp.ndarray, s: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Fake-quantize rows of w given (broadcastable) scales s."""
    if spec.fmt in ("int4", "int8"):
        bits = 4 if spec.fmt == "int4" else 8
        return int_quantize(w, s, 0.0, bits, signed=True)
    if spec.fmt in ("fp4", "mxfp4"):
        return fp4_quantize(w, s)
    raise ValueError(spec.fmt)


def rtn(w: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Round-to-nearest with the Appendix-B scale policy."""
    if not spec.enabled:
        return w
    s = row_scales(w.astype(jnp.float32), spec)
    return _quantize_rows(w.astype(jnp.float32), s, spec).astype(w.dtype)


def _upper_cholesky_inv(h: jnp.ndarray) -> jnp.ndarray:
    """Upper Cholesky factor U of H⁻¹ (H⁻¹ = UᵀU), via H = LLᵀ."""
    hinv = jnp.linalg.inv(h)
    # Symmetrize for numerical safety before factorization.
    hinv = 0.5 * (hinv + hinv.T)
    L = jnp.linalg.cholesky(hinv)
    return L.T


@functools.partial(jax.jit, static_argnames=("spec", "act_order"))
def gptq(w: jnp.ndarray, h: jnp.ndarray, spec: QuantSpec,
         *, damp_frac: float = 0.01, act_order: bool = True,
         damp_sigma: float | None = None) -> jnp.ndarray:
    """GPTQ/OPTQ error-correcting rounding.

    w: [d_in, d_out], h: [d_in, d_in] = XᵀX. Returns fake-quantized W whose
    rows were rounded sequentially with error diffusion. `damp_sigma`
    overrides the damping to λ = damp_sigma·σ₁(H) (used by Qronos).
    """
    if not spec.enabled:
        return w
    w = w.astype(jnp.float32)
    h = h.astype(jnp.float32)
    d = w.shape[0]

    # Dead input channels: H_ii == 0 ⇒ pin to 1 (their weights don't matter).
    diag = jnp.diagonal(h)
    dead = diag <= 0.0
    h = h + jnp.diag(jnp.where(dead, 1.0, 0.0))

    if damp_sigma is not None:
        lam = damp_sigma * _sigma_max(h)
    else:
        lam = damp_frac * jnp.mean(jnp.diagonal(h))
    h = h + lam * jnp.eye(d, dtype=jnp.float32)

    if act_order:
        order = jnp.argsort(-jnp.diagonal(h), stable=True)
        w = w[order]
        h = h[order][:, order]

    scales = row_scales(w, spec)
    scales = jnp.broadcast_to(scales, w.shape)
    u = _upper_cholesky_inv(h)

    idx = jnp.arange(d)

    def step(carry, i):
        wc = carry
        wi = wc[i]
        qi = _quantize_rows(wi, scales[i], spec)
        err = (wi - qi) / u[i, i]
        mask = (idx > i).astype(jnp.float32)
        wc = wc - (mask * u[i])[:, None] * err[None, :]
        wc = wc.at[i].set(qi)
        return wc, None

    w, _ = jax.lax.scan(step, w, jnp.arange(d))

    if act_order:
        inv = jnp.argsort(order)
        w = w[inv]
    return w


def _sigma_max(h: jnp.ndarray, iters: int = 32) -> jnp.ndarray:
    """Largest singular value of symmetric PSD h via power iteration."""
    v = jnp.ones((h.shape[0],), jnp.float32) / jnp.sqrt(h.shape[0])

    def body(_, v):
        v = h @ v
        return v / jnp.maximum(jnp.linalg.norm(v), 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v)
    return jnp.linalg.norm(h @ v)


@functools.partial(jax.jit, static_argnames=("spec", "act_order"))
def qronos(w: jnp.ndarray, h_q: jnp.ndarray, spec: QuantSpec,
           *, c_qx: jnp.ndarray | None = None, alpha: float = 1e-3,
           act_order: bool = True) -> jnp.ndarray:
    """Qronos rounding: past-correcting re-fit + GPTQ recursion.

    h_q = X̃ᵀX̃ (quantized inputs), c_qx = X̃ᵀX (quantized × full-precision).
    When c_qx is None the re-fit is skipped (X̃ = X) and this is GPTQ with
    Qronos' σ₁-based damping.
    """
    if not spec.enabled:
        return w
    w = w.astype(jnp.float32)
    h_q = h_q.astype(jnp.float32)
    if c_qx is not None:
        lam = alpha * _sigma_max(h_q)
        a = h_q + lam * jnp.eye(h_q.shape[0], dtype=jnp.float32)
        # Shape the future: remaining (all) weights re-fit against X̃.
        w = jax.scipy.linalg.solve(a, c_qx.astype(jnp.float32) @ w,
                                   assume_a="pos")
    return gptq(w, h_q, spec, act_order=act_order, damp_sigma=alpha)
