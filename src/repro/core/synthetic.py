"""Synthetic outlier structure for PTQ validation.

Freshly initialized models have Gaussian activations with no outliers, so
rotation-based PTQ has nothing to suppress (and can even look worse under
MSE). Real LLMs concentrate activation mass in a few channels. This helper
injects that structure — a few systematically large norm-scale channels —
so the paper's orderings (rotation > none, MassDiff > identity, PeRQ closes
the block→full gap) are measurable on CPU-scale models without pretrained
checkpoints. The end-to-end example instead *trains* a small model, which
develops outliers organically; both paths are exercised by the benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def inject_outlier_channels(params, *, strength: float = 8.0,
                            strength2: float = 5.0, seed: int = 0,
                            hidden_strength: float = 16.0):
    """Create LLM-like activation outliers.

    Two mechanisms:
      * norm-scale outliers — a sparse set of large γ channels (residual
        stream outliers, as observed in real LLMs);
      * FFN hidden outliers — a *function-preserving* reparametrization
        w_up[:, c] ← s·w_up[:, c], w_down[c, :] ← w_down[c, :]/s for sparse
        c: the model function is unchanged, but the down-projection input
        (the paper's R̃₃ site) now concentrates its ℓ₁ mass in a few
        channels exactly like trained LLMs do.
    """
    p = jax.tree.map(np.array, params)
    rng = np.random.default_rng(seed)

    def scale_vec(s):
        d = s.shape[-1]
        idx1 = rng.choice(d, size=max(1, d // 24), replace=False)
        idx2 = rng.choice(d, size=max(1, d // 32), replace=False)
        s[..., idx1] *= strength
        s[..., idx2] *= strength2
        return s

    def reparam_ffn(ffn):
        if "w_up" not in ffn:
            return
        f = ffn["w_up"].shape[-1]
        idx = rng.choice(f, size=max(1, f // 16), replace=False)
        scales = rng.uniform(hidden_strength / 2, hidden_strength,
                             size=len(idx)).astype(np.float32)
        ffn["w_up"][..., idx] *= scales
        if "w_gate" in ffn:
            # gate stays unscaled: silu(g)·(s·u) = s·(silu(g)·u)
            pass
        ffn["w_down"][..., idx, :] /= scales[:, None]

    L = p["layers"]
    for nm in ("attn_norm", "ffn_norm", "norm"):
        if nm in L:
            L[nm]["scale"] = scale_vec(L[nm]["scale"])
    if "ffn" in L:
        reparam_ffn(L["ffn"])
    if "moe" in L:
        reparam_ffn(L["moe"])
        if "shared_up" in L["moe"]:
            sh = {"w_up": L["moe"]["shared_up"],
                  "w_down": L["moe"]["shared_down"]}
            reparam_ffn(sh)
    if "shared_attn" in p:
        for nm in ("attn_norm", "ffn_norm"):
            p["shared_attn"][nm]["scale"] = scale_vec(
                p["shared_attn"][nm]["scale"])
        reparam_ffn(p["shared_attn"]["ffn"])
    p["final_norm"]["scale"] = scale_vec(p["final_norm"]["scale"])
    return jax.tree.map(jnp.asarray, p)
