"""Checkpointing: atomic, async, reshardable (elastic-restart) snapshots.

Layout (one directory per step):
    <dir>/step_000100.tmp/...   → atomic rename → <dir>/step_000100/
        manifest.json           tree structure + shapes + dtypes
        arrays.npz              leaf arrays (addressable data)

Restart contract:
  * `restore(dir)` returns the latest tree as numpy.
  * `restore_sharded(dir, shardings)` device_puts every leaf with the NEW
    sharding tree — the mesh may have a different shape than at save time
    (elastic rescale). Resharding is exercised by the runtime tests.
  * saves are asynchronous (background thread) with `wait()` barriers, and
    a keep-last-k retention policy.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

Params = Any

_SEP = "::"


def _flatten(tree: Params) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out[key] = np.asarray(leaf)
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def _write(self, step: int, flat: dict[str, np.ndarray],
               structure: str):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {
            "step": step,
            "structure": structure,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)      # atomic publish
        self._gc()

    def _gc(self):
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def save(self, step: int, tree: Params, *, blocking: bool = False):
        """Snapshot `tree`. Device→host copy happens synchronously (so the
        caller may mutate afterwards); the file write is backgrounded."""
        self.wait()
        flat = _flatten(jax.tree.map(np.asarray, tree))
        structure = json.dumps(jax.tree_util.tree_structure(tree),
                               default=str)

        def work():
            try:
                self._write(step, flat, structure)
            except Exception as e:      # surfaced on next wait()
                self._error = e

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------
    def restore(self, step: int | None = None,
                target: Params | None = None) -> Params:
        """Load a checkpoint. With `target` (a tree of like-structured
        arrays/ShapeDtypeStructs) the stored leaves are mapped back into
        that structure; otherwise a flat {path: array} dict is returned."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        if target is None:
            return flat
        tflat, treedef = jax.tree_util.tree_flatten_with_path(target)
        leaves = []
        for path, leaf in tflat:
            key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            if key not in flat:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = flat[key]
            want_shape = tuple(leaf.shape)
            if tuple(arr.shape) != want_shape:
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {want_shape}")
            leaves.append(arr.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_sharded(self, target: Params, shardings: Params,
                        step: int | None = None) -> Params:
        """Restore and place with NEW shardings (elastic restart across a
        different mesh shape)."""
        host = self.restore(step, target=target)
        return jax.tree.map(
            lambda a, s: jax.device_put(a, s), host, shardings)
