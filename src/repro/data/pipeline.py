"""Data pipeline: deterministic synthetic LM corpus + file-backed byte
corpus, host-sharded batching with background prefetch.

The synthetic corpus is a first-order Markov chain over a Zipf vocabulary —
it has real learnable structure (bigram statistics), so the end-to-end
example can train a small LM whose perplexity measurably improves, and PTQ
degradation is measurable against it.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

__all__ = ["DataConfig", "SyntheticCorpus", "ByteCorpus", "batch_iterator",
           "Prefetcher"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch_size: int          # per-host batch
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1


class SyntheticCorpus:
    """Markov-Zipf synthetic token stream (deterministic per seed)."""

    def __init__(self, vocab: int, seed: int = 0, branching: int = 32):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        # each token transitions to `branching` preferred successors
        self.succ = rng.integers(0, vocab, size=(vocab, branching))
        probs = 1.0 / np.arange(1, branching + 1)
        self.succ_p = probs / probs.sum()
        base = 1.0 / np.arange(1, vocab + 1) ** 1.1
        self.base_p = base / base.sum()

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length, np.int32)
        tok = int(rng.choice(self.vocab, p=self.base_p))
        for i in range(length):
            out[i] = tok
            if rng.random() < 0.85:
                tok = int(self.succ[tok, rng.choice(len(self.succ_p),
                                                    p=self.succ_p)])
            else:
                tok = int(rng.choice(self.vocab, p=self.base_p))
        return out


class ByteCorpus:
    """File-backed byte-level corpus (vocab 256)."""

    def __init__(self, path: str):
        with open(path, "rb") as f:
            self.data = np.frombuffer(f.read(), dtype=np.uint8)
        if len(self.data) < 2:
            raise ValueError("corpus too small")

    @property
    def vocab(self) -> int:
        return 256

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        start = int(rng.integers(0, max(1, len(self.data) - length - 1)))
        chunk = self.data[start:start + length]
        if len(chunk) < length:
            chunk = np.pad(chunk, (0, length - len(chunk)))
        return chunk.astype(np.int32)


def batch_iterator(corpus, cfg: DataConfig) -> Iterator[dict]:
    """Yields {"tokens": [B, S], "labels": [B, S]} int32 batches.

    Host-sharded: host i draws from a disjoint seed stream, so a multi-host
    launch partitions the data without coordination.
    """
    rng = np.random.default_rng(cfg.seed * cfg.num_hosts + cfg.host_id + 1)
    while True:
        seqs = np.stack([corpus.sample(rng, cfg.seq_len + 1)
                         for _ in range(cfg.batch_size)])
        yield {"tokens": seqs[:, :-1].astype(np.int32),
               "labels": seqs[:, 1:].astype(np.int32)}


class Prefetcher:
    """Background-thread prefetch (keeps the host busy building the next
    batch while the device runs the step)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.it = it
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.thread = threading.Thread(target=self._fill, daemon=True)
        self._stop = False
        self.thread.start()

    def _fill(self):
        try:
            for item in self.it:
                if self._stop:
                    return
                self.q.put(item)
        finally:
            self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop = True
