"""Benchmark runner: one harness per paper table/figure + kernel timings.

Prints ``name,us_per_call,derived`` CSV rows per harness, then each
harness's own table output.
"""
from __future__ import annotations

import io
import sys
import time
from contextlib import redirect_stdout


def _run(name, fn, *args):
    buf = io.StringIO()
    t0 = time.perf_counter()
    status = "ok"
    try:
        with redirect_stdout(buf):
            fn(*args)
    except Exception as e:  # noqa: BLE001
        status = f"fail:{type(e).__name__}"
        buf.write(f"\nERROR {e}\n")
    dt_us = (time.perf_counter() - t0) * 1e6
    print(f"{name},{dt_us:.0f},{status}")
    return name, buf.getvalue()


def main() -> None:
    from . import (fig3_delta, fig45_bounds, massdiff_speed,
                   table1_blocksize, table2_formats, table34_opcounts,
                   table6_permutations)
    from .kernel_bench import main as kernel_main

    jobs = [
        ("table34_opcounts", table34_opcounts.main),
        ("massdiff_speed", massdiff_speed.main),
        ("fig3_delta", fig3_delta.main),
        ("fig45_bounds", fig45_bounds.main),
        ("table1_blocksize_qronos", table1_blocksize.main, []),
        ("table1_blocksize_rtn", table1_blocksize.main,
         ["--rounding", "rtn"]),
        ("table6_permutations", table6_permutations.main),
        ("table2_formats", table2_formats.main),
        ("kernel_bench", kernel_main),
    ]
    print("name,us_per_call,derived")
    outputs = []
    for job in jobs:
        name, fn, *rest = job
        outputs.append(_run(name, fn, *rest))
    print()
    for name, text in outputs:
        print(f"===== {name} =====")
        print(text)


if __name__ == "__main__":
    main()
