"""§6 claim: MassDiff calibrates permutations in under two minutes for
Llama3-8B. We time Algorithm 1 at the real Llama3-8B geometry
(d_ff = 14336, b = 32, 32 layers) on this CPU."""
import time

import numpy as np

from repro.core import massdiff as MD


def main(argv=None):
    d_ff, b, layers = 14336, 32, 32
    rng = np.random.default_rng(0)
    mass = np.abs(rng.laplace(size=(d_ff,))) * rng.uniform(0.5, 10, d_ff)
    t0 = time.perf_counter()
    for _ in range(layers):
        MD.massdiff(mass, b)
    dt = time.perf_counter() - t0
    print("# MassDiff calibration speed (Llama3-8B geometry)")
    print(f"layers,{layers}")
    print(f"d_ff,{d_ff}")
    print(f"total_seconds,{dt:.2f}")
    print(f"under_two_minutes,{dt < 120}")


if __name__ == "__main__":
    main()
