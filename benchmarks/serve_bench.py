"""Serving throughput/latency: paged engine vs legacy slot scheduler.

Drives the same request trace through (a) the legacy dense-slot
`BatchScheduler` (one token per sequence per step, prompts dripped
token-by-token), (b) the paged-KV engine on the bf16 path, and (c) the
paged engine on the packed-int4 path with bf16 and int8 KV pages. A
second set of engine rows covers the non-dense registry families the
generalized state model serves — pure SSM (mamba2, register slots only),
hybrid (zamba2, kv pages + register slots), and MoE (deepseek, kv pages +
routed FFN) — so the per-family serving trajectory is tracked alongside
dense. Reports end-to-end generated tokens/sec and p50/p95 per-token
latency (each generated token inherits the wall time of the engine step
that produced it), and appends the rows to `artifacts/BENCH_serve.json`;
every scheduler row carries a `family` tag and the writer schema-checks
rows before writing, so a partial row fails the smoke job instead of
silently landing in the history.

A shared-prefix multi-turn chat trace runs the same conversations with
the radix prefix cache off and on (`engine_prefix_off` /
`engine_prefix_on` rows) and asserts the win before writing history:
≥50% fewer prefill tokens computed, a nonzero hit-rate, and greedy
tokens bit-identical between the two runs.

A pressured tiered-paging trace (`engine_swap_recompute` /
`engine_swap_swap` rows) evicts a long-running resident under page
pressure through both recovery modes — preempt-and-replay vs
swap-to-host — and asserts before writing: the swap mode replays
strictly fewer prefill tokens, shows lower admission-wait p95, and both
modes produce greedy tokens bit-identical to an unpressured baseline.

Every path is warmed up on the same scheduler/engine object first, so the
numbers measure steady-state scheduling + forward cost, not jit tracing.
On this CPU host the interpret-mode kernel overhead dominates the integer
rows (same caveat as `kernel_bench.py`); the scheduler-level win — chunked
prefill + batched decode vs the token drip — is visible on any backend.

Also benchmarks the attention *data path* in isolation: one decode step's
attention over the same page pool through (a) the legacy gather-to-slab
round trip (gather every page into a contiguous slab, dense attention on
it) vs (b) the block-table-native `kernels.ops.paged_attention` walk.
Each row reports tokens/s and the bytes of KV materialised into a slab
per step — the copy traffic the paged kernel deletes (0 for the paged
row: pages are read in place).

Engine rows additionally record walked-pages-per-decode-step: the pages
the flash-decoding kernel's ragged early-exit actually visits
(`Σ ceil(len/page_size)` per dispatch) vs the padded-batch × full-table
walk of the pre-scale-out kernel — the work reduction of the early-exit,
independent of this host's interpret-mode wall-clock caveat.

    PYTHONPATH=src python benchmarks/serve_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def _trace(n_requests: int, vocab: int, *, seed: int = 0,
           lo: int = 3, hi: int = 12):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n_requests)]


def _drive(submit, step, pending, total_new):
    """Warmup round (compile every shape on the same object), then a
    measured round; returns (wall_s, per-token latencies in seconds)."""
    submit()
    while pending():
        step()
    submit()
    lat, done_tokens, steps = [], 0, 0
    t_start = time.perf_counter()
    while pending():
        t0 = time.perf_counter()
        step()
        dt = time.perf_counter() - t0
        steps += 1
        new = total_new() - done_tokens
        done_tokens = total_new()
        lat.extend([dt] * new)
    return time.perf_counter() - t_start, lat, steps


def bench_legacy(model, params, prompts, max_new, slots, max_len):
    from repro.serve.step import BatchScheduler, Request

    sched = BatchScheduler(model, params, slots=slots, max_len=max_len)
    done: list = []

    def submit():
        done.clear()
        for rid, p in enumerate(prompts):
            sched.submit(Request(rid=rid, prompt=list(p), max_new=max_new))

    wall, lat, steps = _drive(
        submit, lambda: done.extend(sched.step()),
        lambda: bool(sched.queue or sched.active),
        lambda: sum(len(r.generated) for r in done)
        + sum(len(r.generated) for r in sched.active.values()))
    return wall, lat, steps, None


def bench_engine(adapter, prompts, max_new, slots, max_len, page_size,
                 prefill_chunk):
    from repro.serve.engine import (EngineRequest, SamplingParams,
                                    ServeEngine, pages_for)

    n_pages = slots * pages_for(max_len, page_size) + 1
    eng = ServeEngine(adapter, n_pages=n_pages, page_size=page_size,
                      max_seqs=slots, prefill_chunk=prefill_chunk)
    done: list = []

    def submit():
        done.clear()
        # reset at each round boundary so the registry covers exactly the
        # measured trace (the warmup round re-runs the same requests)
        eng.reset_metrics()
        for rid, p in enumerate(prompts):
            eng.submit(EngineRequest(
                rid=rid, prompt=list(p),
                sampling=SamplingParams(max_new=max_new)))

    wall, lat, steps = _drive(
        submit, lambda: done.extend(eng.step()),
        lambda: bool(eng.queue or eng.active),
        lambda: sum(len(r.generated) for r in done)
        + sum(len(r.generated) for r in eng.active))
    # engine accounting comes off the registry snapshot — the same export
    # surface the launcher writes and CI validates — not engine internals.
    # walked-pages: what the ragged early-exit actually walked vs the
    # padded-batch × full-table walk of the pre-flash-decode kernel (per
    # attention dispatch, per layer)
    c = eng.metrics_snapshot()["counters"]
    pages = {"pages_walked": c["engine.pages_walked"],
             "pages_walked_dense": c["engine.pages_walked_dense"]}
    return wall, lat, steps, pages


def bench_burst(adapter, *, n_tenants, prompt_len, max_new, page_size,
                vocab, seed=7):
    """Synthetic bursty multi-tenant trace: `n_tenants` equal-priority
    requests arrive in one burst against a page pool deliberately too
    small for everyone's worst case (capacity = 3 worst-case footprints
    when four arrive). Reservation admission head-of-line blocks the
    last tenant until someone finishes; optimistic admission admits the
    whole burst on prompt pages + headroom and recovers from the
    resulting mid-decode exhaustion by preempting and replaying a
    victim. The recorded win is peak page utilization and time-to-first-
    admission p95, both off the validated registry snapshot.
    """
    from repro.serve.engine import (EngineRequest, SamplingParams,
                                    ServeEngine, pages_for)
    from repro.serve.telemetry import validate_snapshot

    worst = pages_for(prompt_len + max_new, page_size)
    n_pages = 3 * worst + 2          # capacity 3·worst + 1 (< 4·worst)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, vocab, size=prompt_len).tolist()
               for _ in range(n_tenants)]

    rows = []
    for mode in ("reserve", "optimistic"):
        eng = ServeEngine(adapter, n_pages=n_pages, page_size=page_size,
                          max_seqs=n_tenants, admission=mode)
        done: list = []

        def submit():
            done.clear()
            eng.reset_metrics()
            for rid, p in enumerate(prompts):
                eng.submit(EngineRequest(
                    rid=rid, prompt=list(p),
                    sampling=SamplingParams(max_new=max_new)))

        # progress counts queued replays too: a preempted request keeps
        # its generated tokens while waiting, and must not be re-counted
        # as fresh progress when re-admitted
        wall, lat, steps = _drive(
            submit, lambda: done.extend(eng.step()),
            lambda: bool(eng.queue or eng.active),
            lambda: sum(len(r.generated) for r in done)
            + sum(len(r.generated) for r in eng.queue + eng.active))
        snap = eng.metrics_snapshot()
        validate_snapshot(snap)
        c, g, h = snap["counters"], snap["gauges"], snap["histograms"]
        rows.append({
            "path": f"engine_burst_{mode}",
            "family": "dense",
            "admission": mode,
            "tokens_per_s": round(len(lat) / wall, 2),
            "gen_tokens": len(lat),
            "steps": steps,
            "wall_s": round(wall, 3),
            "peak_util": round(g["engine.pages.utilization_peak"], 4),
            "admission_wait_p95_ms": round(
                (h["engine.admission.wait_s"]["p95"] or 0.0) * 1e3, 3),
            "preemptions": c["engine.preemptions"],
            "replayed_prefill_tokens": c["engine.replayed_prefill_tokens"],
        })

    res, opt = rows
    # the whole point of optimistic+preemption: strictly higher peak
    # utilization AND strictly lower time-to-first-admission on the same
    # burst — refuse to record rows that don't show the win
    if not (opt["peak_util"] > res["peak_util"]
            and opt["admission_wait_p95_ms"] < res["admission_wait_p95_ms"]):
        raise SystemExit(
            "bursty trace did not show the optimistic-admission win: "
            f"peak_util {opt['peak_util']} vs {res['peak_util']}, "
            f"wait p95 {opt['admission_wait_p95_ms']}ms vs "
            f"{res['admission_wait_p95_ms']}ms")
    return rows


def bench_swap(adapter, *, vocab, seed=13):
    """Tiered-paging trace: swap-to-host vs recompute-by-replay under
    identical page pressure.

    Two long residents decode against a pool sized so their combined
    growth must evict one of them; two short requests then arrive and
    wait for seats. The recompute mode (no host tier) preempts the
    victim and replays its whole `prompt + generated` stream; the swap
    mode parks the victim's pages in an 8 MiB host tier and patches them
    back, replaying nothing. Both runs — and an unpressured baseline
    with room for all four worst cases — must produce bit-identical
    greedy tokens; the recorded (and asserted) win is fewer replayed
    prefill tokens AND lower admission-wait p95 for the swap mode, both
    off the validated registry snapshot.
    """
    from repro.serve.engine import (EngineRequest, SamplingParams,
                                    ServeEngine, pages_for)
    from repro.serve.telemetry import validate_snapshot

    long_len, short_len, max_new = 60, 6, 8
    page_size, n_pages = 8, 18       # 17 usable < 2 pressured worst cases
    rng = np.random.default_rng(seed)
    longs = [rng.integers(0, vocab, size=long_len).tolist()
             for _ in range(2)]
    shorts = [rng.integers(0, vocab, size=short_len).tolist()
              for _ in range(2)]

    def make_req(rid, prompt):
        return EngineRequest(rid=rid, prompt=list(prompt),
                             sampling=SamplingParams(max_new=max_new))

    def run_round(eng, base):
        """Longs decode until pressure evicts one (swap or preempt,
        depending on the engine's mode), then the shorts arrive."""
        eng.reset_metrics()
        c = eng.metrics

        def evictions():
            return (c.counter("engine.preemptions").value
                    + c.counter("engine.swap.out").value)

        done: list = []
        t0 = time.perf_counter()
        for i, p in enumerate(longs):
            eng.submit(make_req(base + i, p))
        while evictions() == 0 and (eng.queue or eng.active):
            done.extend(eng.step())
            eng.check_books()
        for i, p in enumerate(shorts):
            eng.submit(make_req(base + 2 + i, p))
        done.extend(eng.run())
        eng.check_books()
        wall = time.perf_counter() - t0
        return {r.rid - base: list(r.generated) for r in done}, wall

    rows = []
    outs_by_mode = {}
    for mode, kw in (("recompute", dict(swap_policy="never")),
                     ("swap", dict(swap_host_mb=8.0, swap_policy="always"))):
        eng = ServeEngine(adapter, n_pages=n_pages, page_size=page_size,
                          max_seqs=2, prefill_chunk=8, token_budget=64,
                          headroom_pages=0, max_preemptions=10, **kw)
        run_round(eng, 100)       # warmup: compile every path incl. swap
        outs, wall = run_round(eng, 0)
        snap = eng.metrics_snapshot()
        validate_snapshot(snap)
        c, h = snap["counters"], snap["histograms"]
        outs_by_mode[mode] = outs
        rows.append({
            "path": f"engine_swap_{mode}",
            "family": "dense",
            "tokens_per_s": round(c["engine.generated_tokens"] / wall, 2),
            "gen_tokens": c["engine.generated_tokens"],
            "wall_s": round(wall, 3),
            "preemptions": c["engine.preemptions"],
            "replayed_prefill_tokens": c["engine.replayed_prefill_tokens"],
            "swap_out": c["engine.swap.out"],
            "swap_in": c["engine.swap.in"],
            "swap_bytes": c["engine.swap.bytes"],
            "swap_retries": c["engine.swap.retries"],
            "swap_fallbacks": c["engine.swap.fallbacks"],
            "admission_wait_p95_ms": round(
                (h["engine.admission.wait_s"]["p95"] or 0.0) * 1e3, 3),
        })

    # unpressured baseline: every request fits its worst case, so no
    # eviction of any kind — the greedy tokens both pressured modes must
    # reproduce exactly
    base_pages = 4 * pages_for(long_len + max_new, page_size) + 1
    eng = ServeEngine(adapter, n_pages=base_pages, page_size=page_size,
                      max_seqs=4, prefill_chunk=8, token_budget=64)
    for i, p in enumerate(longs + shorts):
        eng.submit(make_req(i, p))
    base_outs = {r.rid: list(r.generated) for r in eng.run()}

    for mode, outs in outs_by_mode.items():
        if outs != base_outs:
            raise SystemExit(
                f"{mode} mode perturbed greedy tokens under pressure: "
                + "; ".join(f"rid{r}: {outs.get(r)} != {base_outs[r]}"
                            for r in base_outs
                            if outs.get(r) != base_outs[r]))
    rec, sw = rows
    if not (rec["preemptions"] >= 1 and sw["swap_out"] >= 1
            and sw["swap_in"] >= 1):
        raise SystemExit(
            "swap trace never hit pressure: "
            f"recompute preemptions {rec['preemptions']}, "
            f"swap out/in {sw['swap_out']}/{sw['swap_in']}")
    if not (sw["replayed_prefill_tokens"] < rec["replayed_prefill_tokens"]
            and sw["admission_wait_p95_ms"] < rec["admission_wait_p95_ms"]):
        raise SystemExit(
            "pressured trace did not show the swap-tier win: "
            f"replayed tokens {sw['replayed_prefill_tokens']} vs "
            f"{rec['replayed_prefill_tokens']}, wait p95 "
            f"{sw['admission_wait_p95_ms']}ms vs "
            f"{rec['admission_wait_p95_ms']}ms")
    return rows


def bench_prefix(adapter, *, vocab, n_convs=2, n_turns=3, system_len=32,
                 user_len=5, max_new=3, page_size=8, seed=11):
    """Shared-prefix multi-turn chat trace: prefix cache off vs on.

    `n_convs` conversations share one `system_len`-token system prompt;
    each turn's prompt is the previous turn's full stream (prompt +
    greedy completion) plus `user_len` fresh user tokens — the radix
    tree serves both the cross-conversation system prefix and each
    conversation's own history, so turn-k prefill shrinks from the whole
    transcript to roughly the new user tokens. Geometry is alignment-
    friendly on purpose (`prefill_chunk == page_size`, system prompt a
    page multiple): cache hits land on page boundaries, so the cached
    rows the on-run reads are the bitwise rows the off-run recomputes.

    Asserts — before any row is written — that the cache-on run (a)
    computes ≤ 50% of the off-run's prefill tokens, (b) records a
    nonzero prefix hit-rate, and (c) produces bit-identical greedy
    tokens for every (conversation, turn).
    """
    from repro.serve.engine import (EngineRequest, SamplingParams,
                                    ServeEngine, pages_for)
    from repro.serve.telemetry import validate_snapshot

    rng = np.random.default_rng(seed)
    system = rng.integers(0, vocab, size=system_len).tolist()
    suffix = [[rng.integers(0, vocab, size=user_len).tolist()
               for _ in range(n_turns)] for _ in range(n_convs)]
    final_len = system_len + n_turns * (user_len + max_new)
    n_pages = (n_convs + 1) * pages_for(final_len, page_size) * 2 + 1

    def run(prefix_on):
        eng = ServeEngine(adapter, n_pages=n_pages, page_size=page_size,
                          max_seqs=2, prefill_chunk=page_size,
                          token_budget=2 + page_size,
                          prefix_cache=prefix_on)
        streams = [system + suffix[c][0] for c in range(n_convs)]
        outs = {}
        rid = 0
        t0 = time.perf_counter()
        for turn in range(n_turns):
            reqs = []
            for c in range(n_convs):
                if turn:
                    streams[c] = (streams[c] + outs[(c, turn - 1)]
                                  + suffix[c][turn])
                r = EngineRequest(rid=rid, prompt=list(streams[c]),
                                  sampling=SamplingParams(max_new=max_new))
                rid += 1
                reqs.append(r)
                eng.submit(r)
            eng.run()
            eng.check_books()
            for c, r in enumerate(reqs):
                outs[(c, turn)] = list(r.generated)
        wall = time.perf_counter() - t0
        snap = eng.metrics_snapshot()
        validate_snapshot(snap)
        return outs, snap, wall

    rows = []
    results = {}
    for on in (False, True):
        outs, snap, wall = run(on)
        c = snap["counters"]
        lookups = c["engine.prefix.hits"] + c["engine.prefix.misses"]
        gen = c["engine.generated_tokens"]
        results[on] = (outs, c)
        rows.append({
            "path": "engine_prefix_on" if on else "engine_prefix_off",
            "family": "dense",
            "tokens_per_s": round(gen / wall, 2),
            "gen_tokens": gen,
            "wall_s": round(wall, 3),
            "prefill_tokens": c["engine.prefill_tokens"],
            "prefix_hits": c["engine.prefix.hits"],
            "prefix_hit_tokens": c["engine.prefix.hit_tokens"],
            "prefix_hit_rate": round(
                c["engine.prefix.hits"] / lookups, 4) if lookups else 0.0,
            "cow_copies": c["engine.prefix.cow_copies"],
        })

    (outs_off, c_off), (outs_on, c_on) = results[False], results[True]
    off, on = c_off["engine.prefill_tokens"], c_on["engine.prefill_tokens"]
    if outs_on != outs_off:
        raise SystemExit(
            "prefix cache perturbed greedy tokens: "
            + "; ".join(f"conv{c} turn{t}: {outs_on[(c, t)]} != "
                        f"{outs_off[(c, t)]}"
                        for (c, t) in outs_off
                        if outs_on[(c, t)] != outs_off[(c, t)]))
    if not (on * 2 <= off and c_on["engine.prefix.hits"] > 0):
        raise SystemExit(
            "shared-prefix trace did not show the radix-cache win: "
            f"prefill tokens {on} (cache on) vs {off} (off), "
            f"hits {c_on['engine.prefix.hits']}")
    return rows


def bench_attn_data_path(cfg, *, page_size, slots, seq_len, iters):
    """Slab-gather vs paged-kernel decode attention over one page pool.

    The batch is ragged (lengths span 25%..100% of `seq_len`) so the
    paged rows also show the flash-decoding early-exit: the slab path
    gathers — and the pre-flash-decode kernel walked — every table column
    of every slot, while the kernel now walks `Σ ceil(len/page_size)`
    live pages per step (reported as pages_walked_per_step).
    """
    import math

    import jax.numpy as jnp

    from repro.kernels import ops as kops
    from repro.serve.engine import pages as PG
    from repro.serve.engine.pages import pages_for

    try:
        from .common import ragged_paged_batch
    except ImportError:                  # run as a plain script
        from common import ragged_paged_batch

    nl, kh, dh, h = (cfg.n_layers, cfg.n_kv_heads, cfg.head_dim,
                     cfg.n_heads)
    per_seq = pages_for(seq_len, page_size)
    rng = np.random.default_rng(0)
    lengths, n_pages, table, positions = ragged_paged_batch(
        slots, seq_len, page_size)
    pool = {
        "k": jnp.asarray(rng.standard_normal(
            (nl, n_pages, page_size, kh, dh)), jnp.float32),
        "v": jnp.asarray(rng.standard_normal(
            (nl, n_pages, page_size, kh, dh)), jnp.float32),
    }
    bt = jnp.asarray(table, jnp.int32)
    qpos = jnp.asarray(positions, jnp.int32)
    lens = jnp.asarray(lengths, jnp.int32)
    q = jnp.asarray(rng.standard_normal((nl, slots, 1, h, dh)), jnp.float32)

    def slab_attn(ql, k_all, v_all):
        g = h // kh
        qg = ql.reshape(slots, 1, kh, g, dh)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_all) / math.sqrt(dh)
        valid = jnp.arange(k_all.shape[1])[None, None, :] <= qpos[:, :, None]
        logits = jnp.where(valid[:, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_all)
        return out.reshape(slots, 1, h, dh)

    @jax.jit
    def slab_step(pool, q):
        slab = PG.gather_pages(pool, bt)
        return jnp.stack([slab_attn(q[l], slab["k"][l], slab["v"][l])
                          for l in range(nl)])

    @jax.jit
    def paged_step(pool, q):
        return jnp.stack([
            kops.paged_attention(
                q[l], {"k": pool["k"][l], "v": pool["v"][l]}, bt, qpos,
                lens)
            for l in range(nl)])

    slab_bytes = 2 * nl * slots * per_seq * page_size * kh * dh * 4
    walked = {"attn_slab_gather": slots * per_seq,
              "attn_paged_kernel": sum(pages_for(n, page_size)
                                       for n in lengths)}

    rows = []
    for name, fn, gathered in (("attn_slab_gather", slab_step, slab_bytes),
                               ("attn_paged_kernel", paged_step, 0)):
        fn(pool, q).block_until_ready()            # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(pool, q)
        out.block_until_ready()
        wall = time.perf_counter() - t0
        rows.append({
            "path": name,
            "family": "dense",
            "tokens_per_s": round(slots * iters / wall, 2),
            "gathered_bytes_per_step": gathered,
            "pages_walked_per_step": walked[name],
            "seq_len": seq_len,
            "page_size": page_size,
            "wall_s": round(wall, 4),
        })
    return rows


def _check_schema(rows):
    """Every row must carry `family` and `tokens_per_s` — a partial row
    (a bench path that crashed mid-collection or forgot its tag) fails the
    smoke job instead of silently writing incomplete JSON history."""
    for row in rows:
        missing = [k for k in ("family", "tokens_per_s") if k not in row]
        if missing:
            raise ValueError(
                f"bench row {row.get('path', '?')!r} is missing required "
                f"field(s) {missing}; refusing to write partial history")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace for CI: compiles every engine jit "
                    "path once, minimal wall time")
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_serve.json"))
    args = ap.parse_args(argv)

    from repro.configs.registry import get_config
    from repro.models.transformer import build_model
    from repro.serve.engine import as_servable
    from repro.serve.quantized import QuantizedDenseLM, pack_dense_params

    cfg = get_config("llama3-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    packed = pack_dense_params(params, cfg)

    # the serving-realistic trace is prompt-heavy (RAG/chat prompts are
    # much longer than completions) — exactly where chunked prefill beats
    # the legacy one-token-per-step prompt drip
    if args.smoke:
        n_req, max_new, lo, hi = 3, 3, 3, 12
    else:
        n_req, max_new, lo, hi = 12, 8, 16, 48
    slots, max_len, page, chunk = 2, 64, 8, 8
    prompts = _trace(n_req, cfg.vocab, lo=lo, hi=hi)
    total = sum(len(p) for p in prompts) + n_req * max_new

    # per-family engine rows: the generalized state model serves the
    # non-dense registry families through the same scheduler. Family
    # traces stay smoke-sized in both modes (the point is the per-family
    # trajectory, not a long trace)
    def family_run(arch, **model_kw):
        fcfg = get_config(arch).reduced()
        fmodel = build_model(fcfg, **model_kw)
        fparams = fmodel.init(jax.random.PRNGKey(0))
        fprompts = _trace(3, fcfg.vocab, lo=3, hi=12)
        return lambda: bench_engine(as_servable(fmodel, fparams), fprompts,
                                    3, slots, max_len, page, chunk)

    runs = {
        "legacy_sched_bf16": ("dense",
            lambda: bench_legacy(model, params, prompts, max_new, slots,
                                 max_len)),
        "engine_bf16": ("dense",
            lambda: bench_engine(as_servable(model, params), prompts,
                                 max_new, slots, max_len, page, chunk)),
        "engine_int4_kvbf16": ("dense",
            lambda: bench_engine(
                as_servable(QuantizedDenseLM(cfg, block_size=16), packed),
                prompts, max_new, slots, max_len, page, chunk)),
        "engine_int4_kv8": ("dense",
            lambda: bench_engine(
                as_servable(QuantizedDenseLM(cfg, block_size=16, kv_bits=8),
                            packed),
                prompts, max_new, slots, max_len, page, chunk)),
        "engine_bf16_ssm": ("ssm", family_run("mamba2-1.3b")),
        "engine_bf16_hybrid": ("hybrid", family_run("zamba2-1.2b")),
        "engine_bf16_moe": ("moe", family_run("deepseek-moe-16b")),
    }

    rows = []
    print("path,family,tokens_per_s,p50_ms,p95_ms,gen_tokens,steps,wall_s,"
          "pages_walked_per_step,pages_dense_per_step")
    for name, (family, fn) in runs.items():
        wall, lat, steps, pages = fn()
        gen = len(lat)
        # `steps` = scheduler iterations (≈ batched forward passes): the
        # hardware-independent scheduling win — chunked prefill needs far
        # fewer forwards per served token than the legacy token drip, even
        # where CPU dispatch overhead hides it in wall time
        row = {
            "path": name,
            "family": family,
            "tokens_per_s": round(gen / wall, 2),
            "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
            "p95_ms": round(float(np.percentile(lat, 95)) * 1e3, 3),
            "gen_tokens": gen,
            "steps": steps,
            "wall_s": round(wall, 3),
        }
        if pages is not None:
            # the ragged early-exit's work reduction per attention
            # dispatch: live pages walked vs the padded batch × full
            # table the pre-flash-decode kernel walked
            row["pages_walked_per_step"] = round(
                pages["pages_walked"] / max(steps, 1), 2)
            row["pages_dense_per_step"] = round(
                pages["pages_walked_dense"] / max(steps, 1), 2)
        rows.append(row)
        print(",".join(str(row.get(k, "")) for k in (
            "path", "family", "tokens_per_s", "p50_ms", "p95_ms",
            "gen_tokens", "steps", "wall_s", "pages_walked_per_step",
            "pages_dense_per_step")))

    # bursty multi-tenant trace: reservation vs optimistic+preemption on
    # an identical undersized pool — the ROADMAP item 1 utilization claim
    # as a recorded (and asserted) number
    for row in bench_burst(as_servable(model, params), n_tenants=4,
                           prompt_len=8, max_new=8 if args.smoke else 16,
                           page_size=8, vocab=cfg.vocab):
        rows.append(row)
        print(",".join(str(row[k]) for k in row))

    # tiered-paging trace: swap-to-host vs recompute-by-replay under the
    # same pressure — asserts zero-replay re-admission, lower admission
    # wait, and bit-identical tokens vs an unpressured baseline
    for row in bench_swap(as_servable(model, params), vocab=cfg.vocab):
        rows.append(row)
        print(",".join(str(row[k]) for k in row))

    # shared-prefix multi-turn trace: radix cache off vs on on identical
    # conversations — asserts ≥50% prefill reduction, a nonzero hit-rate,
    # and bit-identical greedy tokens before any row is recorded
    for row in bench_prefix(as_servable(model, params), vocab=cfg.vocab):
        rows.append(row)
        print(",".join(str(row[k]) for k in row))

    # attention data path in isolation: the slab round trip vs the
    # block-table-native kernel walk over the identical page pool
    seq_len, iters = (64, 3) if args.smoke else (512, 20)
    for row in bench_attn_data_path(cfg, page_size=16, slots=4,
                                    seq_len=seq_len, iters=iters):
        rows.append(row)
        print(",".join(str(row[k]) for k in row))

    from repro.serve.telemetry import SCHEMA_VERSION

    out = {
        "bench": "serve",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "metrics_schema_version": SCHEMA_VERSION,
        "config": {"arch": "llama3-1b/reduced", "requests": n_req,
                   "max_new": max_new, "slots": slots, "max_len": max_len,
                   "page_size": page, "prefill_chunk": chunk,
                   "trace_tokens": total},
        "rows": rows,
    }
    _check_schema(rows)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    history = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            history = json.load(f).get("history", [])
    # trajectory guard: a row stamped with an older telemetry schema than
    # the history's newest means this checkout regressed (or the schema
    # bump was reverted) — refuse to append rather than mix schemas
    newest = max((h.get("metrics_schema_version", 0) for h in history),
                 default=0)
    if out["metrics_schema_version"] < newest:
        raise SystemExit(
            f"refusing to append a metrics_schema_version="
            f"{out['metrics_schema_version']} row to a history whose newest "
            f"is {newest}")
    history.append(out)
    with open(args.out, "w") as f:
        json.dump({"history": history}, f, indent=1)
    print(f"wrote {args.out} ({len(history)} entries)")


if __name__ == "__main__":
    main()
