"""Table 2: perplexity across data formats × pipeline compositions."""
from __future__ import annotations

from repro.core import pipeline as PL
from repro.core.quantizers import QuantSpec

from .common import bench_model, eval_ppl, quantize_and_eval

METHODS = ["mr_rtn", "mr_gptq", "mr_qronos", "brq_spin", "perq_star",
           "perq_dagger"]
FORMATS = ["int4", "fp4", "mxfp4"]


def run():
    cfg, model, params, corpus = bench_model()
    rows = [("bf16", "-", eval_ppl(model, params, corpus))]
    for fmt in FORMATS:
        for name in METHODS:
            ptq = PL.preset(name,
                            weight_spec=QuantSpec(fmt=fmt),
                            act_spec=QuantSpec(fmt=fmt),
                            cayley_steps=8)
            ppl = quantize_and_eval(model, params, corpus, ptq, n_eval=4)
            rows.append((name, fmt, ppl))
    return rows


def main(argv=None):
    rows = run()
    print("# Table2 surrogate")
    print("method,format,ppl")
    for name, fmt, ppl in rows:
        print(f"{name},{fmt},{ppl:.3f}")


if __name__ == "__main__":
    main()
