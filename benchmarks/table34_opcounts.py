"""Tables 3 & 4: rotation op counts (EXACT reproduction — these are
arithmetic identities, so the numbers match the paper digit-for-digit)."""
from repro.core import hadamard as hd

T3 = [("llama3-1b/3b", 8192), ("llama3-8b", 14336), ("qwen3-1.7b", 6144),
      ("qwen3-4b", 9728), ("qwen3-8b", 12288)]


def main(argv=None):
    print("# Table 3: block vs full-vector rotation ops")
    print("model,d,b32,b128,b512,full")
    for name, d in T3:
        print(f"{name},{d},{hd.ops_block(d,32)},{hd.ops_block(d,128)},"
              f"{hd.ops_block(d,512)},{hd.ops_full_vector(d)}")
    print("# Table 4: non-pow2 full rotation methods")
    print("model,d,matmul,butterfly_matmul,ours")
    for name, d in [("llama3-8b", 14336), ("qwen3-0.6b", 3072),
                    ("qwen3-1.7b", 6144), ("qwen3-4b", 9728),
                    ("qwen3-8b", 12288)]:
        print(f"{name},{d},{hd.ops_dense_matmul(d)},"
              f"{hd.ops_butterfly_matmul(d)},{hd.ops_optimized(d)}")


if __name__ == "__main__":
    main()
