"""Shared benchmark harness.

The paper evaluates on pretrained LLMs; offline we (a) train a small
llama3-family LM on a synthetic Markov-Zipf corpus until its perplexity is
meaningfully below uniform, (b) inject LLM-like outlier channels, then (c)
run the PTQ pipelines and report perplexity on held-out data. The paper's
claims checked here are orderings/monotonicities (Table-1/2/5/6 trends),
which its theory derives independently of model scale.

The trained checkpoint is cached under artifacts/bench_model/.
"""
from __future__ import annotations

import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.core import pipeline as PL
from repro.core.synthetic import inject_outlier_channels
from repro.data.pipeline import DataConfig, SyntheticCorpus, batch_iterator
from repro.models.transformer import build_model
from repro.optim import adamw
from repro.train.step import TrainConfig, make_train_step

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")

BENCH_CFG = dict(n_layers=4, d_model=128, vocab=512, n_heads=4, n_kv_heads=2,
                 head_dim=32, d_ff=256)
TRAIN_STEPS = 300
SEQ, BATCH = 64, 16


def bench_model(train_steps: int = TRAIN_STEPS, *, seed: int = 0,
                refresh: bool = False):
    """Returns (cfg, model, trained_params, corpus). Cached on disk."""
    cfg = get_config("llama3-1b").reduced(**BENCH_CFG)
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab, seed=seed)
    ckdir = os.path.join(ART, "bench_model")
    mgr = CheckpointManager(ckdir, keep=1)
    params_t = model.init(jax.random.PRNGKey(seed))
    if not refresh and mgr.latest_step() == train_steps:
        params = mgr.restore(target={"params": params_t})["params"]
        params = jax.tree.map(jnp.asarray, params)
    else:
        opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=20,
                                    total_steps=train_steps)
        step = jax.jit(make_train_step(model, opt_cfg,
                                       TrainConfig(remat=False)))
        opt = adamw.init_state(opt_cfg, params_t)
        it = batch_iterator(corpus, DataConfig(cfg.vocab, SEQ, BATCH,
                                               seed=seed))
        params = params_t
        for i in range(train_steps):
            params, opt, m = step(params, opt, next(it))
        mgr.save(train_steps, {"params": params}, blocking=True)
    # function-preserving hidden-channel reparametrization only: the bf16
    # model is numerically unchanged, but the down-projection inputs (the
    # paper's R̃₃ site) now concentrate ℓ₁ mass like trained LLMs do.
    params = inject_outlier_channels(params, strength=1.0, strength2=1.0,
                                     hidden_strength=24.0, seed=seed)
    return cfg, model, params, corpus


def eval_ppl(model, params, corpus, *, hooks=None, n_batches: int = 8,
             seed: int = 1234) -> float:
    """Held-out perplexity."""
    from repro.models.transformer import build_model as _bm
    m = _bm(model.cfg, quant_hooks=hooks) if hooks else model
    it = batch_iterator(corpus, DataConfig(model.cfg.vocab, SEQ, BATCH,
                                           seed=seed))
    fwd = jax.jit(lambda p, b: m.loss_fn(p, b)[1]["nll"])
    total = 0.0
    for _ in range(n_batches):
        total += float(fwd(params, next(it)))
    return math.exp(total / n_batches)


def calib_batches(corpus, cfg, n: int = 2, seed: int = 77):
    it = batch_iterator(corpus, DataConfig(cfg.vocab, 128, 8, seed=seed))
    return [next(it) for _ in range(n)]


def quantize_and_eval(model, params, corpus, ptq_cfg: PL.PTQConfig,
                      n_eval: int = 8) -> float:
    cal = calib_batches(corpus, model.cfg)
    res = PL.quantize_model(model, params, cal, ptq_cfg)
    return eval_ppl(model, res.params, corpus, hooks=res.hooks,
                    n_batches=n_eval)


def ragged_paged_batch(batch: int, max_len: int, page_size: int):
    """The shared ragged decode workload for the paged-attention benches.

    Lengths span 25%..100% of `max_len`; each sequence gets distinct
    sequential page ids in a `[batch, max_len/page_size]` table padded
    with the scratch page, and queries sit at the last position. Returns
    (lengths, n_pages, block_table rows, qpos rows) as plain Python/numpy
    so both benches build identical tables and their pages-walked rows
    stay comparable.
    """
    lengths = [max(1, int(max_len * f))
               for f in np.linspace(0.25, 1.0, batch)]
    n_cols = -(-max_len // page_size)
    n_pages = 1 + sum(-(-n // page_size) for n in lengths)
    ids = list(range(1, n_pages))
    table = [[ids.pop(0) for _ in range(-(-n // page_size))]
             + [0] * (n_cols - -(-n // page_size)) for n in lengths]
    qpos = [[n - 1] for n in lengths]
    return lengths, n_pages, table, qpos


class Timer:
    def __init__(self):
        self.t0 = time.perf_counter()

    def us(self) -> float:
        return (time.perf_counter() - self.t0) * 1e6
