"""§Roofline: three-term roofline per (arch × shape × mesh) from the dry-run.

    compute    = FLOPs_per_device / peak_FLOP/s        (197e12 bf16, v5e)
    memory     = HBM_bytes_per_device / HBM_bw         (819e9 B/s)
    collective = collective_bytes_per_device / link_bw (50e9 B/s ICI)

FLOPs/bytes come from the trip-count-corrected HLO walker
(`repro.launch.hlo_analysis`), which XLA's stock `cost_analysis` undercounts
for scanned layer stacks (validated within 2% of the analytic 8·N·D for a
rematerialized train step). Collective bytes are per-device payloads of every
all-gather/all-reduce/reduce-scatter/all-to-all/collective-permute, trip-
corrected. All shapes in the partitioned module are per-device shards, so
each term is per-device seconds; the slowest term is the bottleneck.

MODEL_FLOPS (useful work) = 6·N·D for train (N = matmul params, D = tokens),
2·N_active·D for prefill/decode — the ratio MODEL_FLOPS / HLO_FLOPS exposes
remat/redundant compute.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline [--mesh sp|mp] [--csv out]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12        # bf16 per chip (v5e)
HBM_BW = 819e9             # B/s per chip
LINK_BW = 50e9             # B/s per ICI link

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def _arch_matmul_params(cfg) -> float:
    """Matmul (FLOP-relevant) parameter count per the config."""
    d = cfg.d_model
    n = 0.0
    if cfg.family in ("ssm", "hybrid"):
        d_inner = cfg.d_inner
        state = cfg.ssm_state
        in_dim = 2 * d_inner + 2 * state + cfg.ssm_heads
        n += cfg.n_layers * (d * in_dim + d_inner * d)
        if cfg.family == "hybrid":
            h = cfg.n_heads * cfg.head_dim
            kvd = cfg.n_kv_heads * cfg.head_dim
            n += d * h + 2 * d * kvd + h * d + 3 * d * cfg.d_ff
    else:
        h = cfg.n_heads * cfg.head_dim
        kvd = cfg.n_kv_heads * cfg.head_dim
        attn = d * h + 2 * d * kvd + h * d
        if cfg.uses_moe:
            ffn_active = 3 * d * cfg.moe_d_ff * (cfg.top_k
                                                 + cfg.n_shared_experts)
        else:
            gates = 3 if cfg.act == "silu" else 2
            ffn_active = gates * d * cfg.d_ff
        n += cfg.n_layers * (attn + ffn_active)
    n += 2 * d * cfg.vocab  # embed (gather ~free, but lm_head matmul counts once)
    return n


def model_flops(arch: str, shape: str, kind: str) -> float:
    from repro.configs.registry import get_config
    from repro.models.config import ALL_SHAPES
    cfg = get_config(arch)
    cell = {c.name: c for c in ALL_SHAPES}[shape]
    n = _arch_matmul_params(cfg)
    if kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * cell.global_batch


def load_records(mesh: str) -> list[dict]:
    suffix = "__mp.json" if mesh == "mp" else "__sp.json"
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, f"*{suffix}"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    hc = rec.get("hlo_costs", {})
    if "flops_per_device" not in hc:
        return None
    chips = 1
    for s in rec["mesh"]["shape"]:
        chips *= s
    t_compute = hc["flops_per_device"] / PEAK_FLOPS
    t_memory = hc["bytes_per_device"] / HBM_BW
    t_coll = sum(hc["collective_bytes_by_kind"].values()) / LINK_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)), key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"], rec["kind"])
    mf_dev = mf / chips
    useful = mf_dev / hc["flops_per_device"] if hc["flops_per_device"] else 0
    # roofline fraction: useful work at peak / modeled step time
    t_step = max(t_compute, t_memory, t_coll)
    frac = (mf_dev / PEAK_FLOPS) / t_step if t_step > 0 else 0.0
    mem = rec.get("memory", {})
    hbm = (mem.get("argument_size_bytes", 0)
           + mem.get("temp_size_bytes", 0)) / 2 ** 30
    return {
        "arch": rec["arch"], "shape": rec["shape"], "kind": rec["kind"],
        "mesh": "x".join(str(s) for s in rec["mesh"]["shape"]),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_global": mf, "hlo_flops_dev": hc["flops_per_device"],
        "useful_ratio": useful, "roofline_frac": frac,
        "hbm_gib_dev": hbm,
    }


def fmt_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':9s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'dominant':>10s} "
           f"{'useful':>7s} {'roofline':>9s} {'HBM GiB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:9s} "
            f"{r['t_compute_s']:10.4f} {r['t_memory_s']:10.4f} "
            f"{r['t_collective_s']:10.4f} {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.2f} {r['roofline_frac']:9.3f} "
            f"{r['hbm_gib_dev']:8.1f}")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["sp", "mp"], default="sp")
    ap.add_argument("--csv", default=None)
    args = ap.parse_args(argv)
    rows = [r for r in (roofline_row(rec) for rec in load_records(args.mesh))
            if r is not None]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    print(fmt_table(rows))
    if args.csv:
        import csv
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
        print(f"\nwrote {args.csv}")
    return rows


if __name__ == "__main__":
    main()
