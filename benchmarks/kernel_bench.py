"""Kernel micro-benchmarks (interpret-mode correctness cost + jnp-reference
wall time on CPU; TPU wall-time comes from the roofline, not this host).

Reports per-op bytes/FLOPs and the modeled v5e time for the block-Hadamard
rotation and the fused rotate+quant kernel, plus the measured CPU time of
the jnp reference (sanity anchor, not a perf claim), and an end-to-end
decode-step latency pair for the dispatched serving path — reference
(`use_kernels(False)`) vs kernel dispatch — so the serving-path win (or,
on this CPU host, the interpret-mode overhead) is recorded in the bench
trajectory alongside the per-op numbers.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref as kref

HBM_BW = 819e9
PEAK = 197e12


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def main(argv=None):
    m, d, b = 2048, 8192, 32
    x = jax.random.normal(jax.random.PRNGKey(0), (m, d), jnp.float32)

    rot = jax.jit(lambda x: kref.block_hadamard_ref(x, b))
    us_rot = _time(rot, x)
    fused = jax.jit(lambda x: kref.hadamard_quant_ref(x, b))
    us_fused = _time(fused, x)

    bytes_unfused = m * d * 2 * 2 + (m * d * 2 + m * d * 1 + m * 8)
    bytes_fused = m * d * 2 + m * d * 1 + m * 8
    flops_rot = 2 * m * d * b

    print("# kernel model (v5e bf16) + CPU jnp reference timing")
    print("op,cpu_ref_us,model_bytes,model_flops,v5e_time_us,bound")
    t_mem = m * d * 2 * 2 / HBM_BW * 1e6
    t_cmp = flops_rot / PEAK * 1e6
    print(f"block_hadamard_b{b},{us_rot:.0f},{m*d*4},{flops_rot},"
          f"{max(t_mem,t_cmp):.1f},{'memory' if t_mem>t_cmp else 'compute'}")
    t_mem_f = bytes_fused / HBM_BW * 1e6
    print(f"hadamard_quant_fused_b{b},{us_fused:.0f},{bytes_fused},"
          f"{flops_rot},{max(t_mem_f,t_cmp):.1f},memory")
    saving = 1 - bytes_fused / bytes_unfused
    print(f"fusion_hbm_byte_saving,{saving:.3f}")
    decode_step_bench()


def decode_step_bench(iters: int = 3):
    """ref-vs-dispatched-kernel decode-step latency on the smoke config.

    Both paths run through `QuantizedDenseLM` (jit'd end to end); only the
    `use_kernels` flag differs. On TPU the kernel column is the Mosaic
    path; on CPU it is interpret mode, whose overhead this row makes
    visible rather than hides.
    """
    from repro.configs.registry import get_config
    from repro.models.transformer import build_model
    from repro.serve.quantized import QuantizedDenseLM, pack_dense_params

    cfg = get_config("llama3-1b").reduced()
    model = build_model(cfg)
    packed = pack_dense_params(model.init(jax.random.PRNGKey(0)), cfg)
    qlm = QuantizedDenseLM(cfg, block_size=16)
    tok = jnp.asarray([[7]], jnp.int32)
    idx = jnp.asarray(3, jnp.int32)

    print("serving_path,decode_step_us")
    for label, enabled in (("ref", False), ("kernels", True)):
        with kops.use_kernels(enabled):
            cache = qlm.init_cache(1, 32)
            qlm.decode_step(packed, tok, cache, idx)[0].block_until_ready()
            t0 = time.perf_counter()
            for _ in range(iters):
                out, _ = qlm.decode_step(packed, tok, cache, idx)
                out.block_until_ready()
        print(f"decode_{label},{(time.perf_counter() - t0) / iters * 1e6:.0f}")


if __name__ == "__main__":
    main()
