"""Kernel micro-benchmarks (interpret-mode correctness cost + jnp-reference
wall time on CPU; TPU wall-time comes from the roofline, not this host).

Reports per-op bytes/FLOPs and the modeled v5e time for the block-Hadamard
rotation and the fused rotate+quant kernel, plus the measured CPU time of
the jnp reference (sanity anchor, not a perf claim), and an end-to-end
decode-step latency pair for the dispatched serving path — reference
(`use_kernels(False)`) vs kernel dispatch — so the serving-path win (or,
on this CPU host, the interpret-mode overhead) is recorded in the bench
trajectory alongside the per-op numbers.

Also benchmarks the flash-decoding paged-attention kernel across context
length × head count × `kv_splits`, and — the headline of the scale-out PR —
the ragged early-exit: each row reports the pages walked per decode step
with the walk trimmed to each sequence's live pages versus the full-table
walk the pre-flash-decode kernel did (`batch · n_cols`), plus both wall
times. The work reduction is real even in interpret mode on this host:
skipped columns run neither their page copy nor their softmax update.

Rows are appended to `artifacts/BENCH_kernels.json` so the kernel perf
trajectory is tracked across PRs; `_check_schema` validates every row
against its op family's required fields before anything is written, so a
partial row fails the smoke job instead of landing in the history.

    PYTHONPATH=src python benchmarks/kernel_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref as kref

try:
    from .common import ragged_paged_batch
except ImportError:                      # run as a plain script
    from common import ragged_paged_batch

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")

HBM_BW = 819e9
PEAK = 197e12


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def hadamard_rows():
    m, d, b = 2048, 8192, 32
    x = jax.random.normal(jax.random.PRNGKey(0), (m, d), jnp.float32)

    rot = jax.jit(lambda x: kref.block_hadamard_ref(x, b))
    us_rot = _time(rot, x)
    fused = jax.jit(lambda x: kref.hadamard_quant_ref(x, b))
    us_fused = _time(fused, x)

    bytes_unfused = m * d * 2 * 2 + (m * d * 2 + m * d * 1 + m * 8)
    bytes_fused = m * d * 2 + m * d * 1 + m * 8
    flops_rot = 2 * m * d * b

    rows = []
    print("# kernel model (v5e bf16) + CPU jnp reference timing")
    print("op,cpu_ref_us,model_bytes,model_flops,v5e_time_us,bound")
    t_mem = m * d * 2 * 2 / HBM_BW * 1e6
    t_cmp = flops_rot / PEAK * 1e6
    rows.append({"op": f"block_hadamard_b{b}", "cpu_ref_us": round(us_rot),
                 "model_bytes": m * d * 4, "model_flops": flops_rot,
                 "v5e_time_us": round(max(t_mem, t_cmp), 1),
                 "bound": "memory" if t_mem > t_cmp else "compute"})
    print(f"block_hadamard_b{b},{us_rot:.0f},{m*d*4},{flops_rot},"
          f"{max(t_mem,t_cmp):.1f},{'memory' if t_mem>t_cmp else 'compute'}")
    t_mem_f = bytes_fused / HBM_BW * 1e6
    rows.append({"op": f"hadamard_quant_fused_b{b}",
                 "cpu_ref_us": round(us_fused), "model_bytes": bytes_fused,
                 "model_flops": flops_rot,
                 "v5e_time_us": round(max(t_mem_f, t_cmp), 1),
                 "bound": "memory"})
    print(f"hadamard_quant_fused_b{b},{us_fused:.0f},{bytes_fused},"
          f"{flops_rot},{max(t_mem_f,t_cmp):.1f},memory")
    saving = 1 - bytes_fused / bytes_unfused
    rows.append({"op": "fusion_hbm_byte_saving", "value": round(saving, 3)})
    print(f"fusion_hbm_byte_saving,{saving:.3f}")
    return rows


def decode_step_bench(iters: int = 3):
    """ref-vs-dispatched-kernel decode-step latency on the smoke config.

    Both paths run through `QuantizedDenseLM` (jit'd end to end); only the
    `use_kernels` flag differs. On TPU the kernel column is the Mosaic
    path; on CPU it is interpret mode, whose overhead this row makes
    visible rather than hides.
    """
    from repro.configs.registry import get_config
    from repro.models.transformer import build_model
    from repro.serve.quantized import QuantizedDenseLM, pack_dense_params

    cfg = get_config("llama3-1b").reduced()
    model = build_model(cfg)
    packed = pack_dense_params(model.init(jax.random.PRNGKey(0)), cfg)
    qlm = QuantizedDenseLM(cfg, block_size=16)
    tok = jnp.asarray([[7]], jnp.int32)
    idx = jnp.asarray(3, jnp.int32)

    rows = []
    print("serving_path,decode_step_us")
    for label, enabled in (("ref", False), ("kernels", True)):
        with kops.use_kernels(enabled):
            cache = qlm.init_cache(1, 32)
            qlm.decode_step(packed, tok, cache, idx)[0].block_until_ready()
            t0 = time.perf_counter()
            for _ in range(iters):
                out, _ = qlm.decode_step(packed, tok, cache, idx)
                out.block_until_ready()
        us = (time.perf_counter() - t0) / iters * 1e6
        rows.append({"op": f"decode_{label}", "decode_step_us": round(us)})
        print(f"decode_{label},{us:.0f}")
    return rows


def paged_attention_bench(*, smoke: bool, iters: int = 5):
    """Flash-decoding paged attention: context × heads × kv_splits, and
    the ragged early-exit's pages-walked-per-step reduction.

    One decode step (S = 1) over a ragged batch whose sequence lengths
    span 25%..100% of the context budget. `full_walk` forces
    `seq_lengths` to the table capacity — every instance walks every
    column, which is exactly what the PR 3 `(batch, page)` grid did — and
    `early_exit` passes the true lengths. Pages walked per step is the
    analytic `Σ_b ceil(len_b / page_size)` vs `batch · n_cols`; the wall
    times show the skip is real work deleted (no page copy, no softmax
    update), interpret-mode overhead included.
    """
    page_size, batch, dh = 16, 4, 64
    cases = ([(64, 2, 4, 1), (64, 2, 4, 4)] if smoke else
             [(256, 2, 8, 1), (256, 2, 8, 4),
              (1024, 2, 8, 1), (1024, 2, 8, 4), (1024, 2, 8, 8),
              (1024, 8, 32, 4)])
    rng = np.random.default_rng(0)
    rows = []
    print("op,ctx,kv_heads,q_heads,kv_splits,pages_per_step,us_per_step")
    for ctx, kh, h, kv_splits in cases:
        n_cols = ctx // page_size
        lengths, n_pages, table, positions = ragged_paged_batch(
            batch, ctx, page_size)
        kv = {"k": jnp.asarray(rng.standard_normal(
                  (n_pages, page_size, kh, dh)), jnp.float32),
              "v": jnp.asarray(rng.standard_normal(
                  (n_pages, page_size, kh, dh)), jnp.float32)}
        bt = jnp.asarray(table, jnp.int32)
        qpos = jnp.asarray(positions, jnp.int32)
        q = jnp.asarray(rng.standard_normal((batch, 1, h, dh)), jnp.float32)
        true_lens = jnp.asarray(lengths, jnp.int32)
        full_lens = jnp.full((batch,), n_cols * page_size, jnp.int32)

        walked = {"full_walk": batch * n_cols,
                  "early_exit": sum(-(-n // page_size) for n in lengths)}
        for label, lens in (("full_walk", full_lens),
                            ("early_exit", true_lens)):
            fn = jax.jit(lambda lens=lens: kops.paged_attention(
                q, kv, bt, qpos, lens, kv_splits=kv_splits))
            fn().block_until_ready()
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn()
            out.block_until_ready()
            us = (time.perf_counter() - t0) / iters * 1e6
            rows.append({
                "op": f"paged_attention_{label}", "ctx": ctx,
                "kv_heads": kh, "q_heads": h, "kv_splits": kv_splits,
                "page_size": page_size, "batch": batch,
                "pages_per_step": walked[label],
                "us_per_step": round(us, 1),
            })
            print(f"paged_attention_{label},{ctx},{kh},{h},{kv_splits},"
                  f"{walked[label]},{us:.1f}")
    return rows


def swap_io_bench(*, smoke: bool, iters: int = 5):
    """Host<->device swap-tier page I/O: the fused gather+device_get
    (swap-out) and device_put+scatter (swap-in) round trip the tiered
    paging engine pays per evicted page, as a measured bandwidth.

    A synthetic `PagedKVCache` with an attached host pool swaps one
    sequence's pages out and back per cycle; the first cycle compiles
    both fused dispatches and is discarded. The bytes/cycle is the
    per-direction payload (`pages · page_bytes`) — the quantity the
    scheduler's swap-vs-replay cost rule weighs against replayed
    prefill tokens.
    """
    from repro.serve.engine.pages import PagedKVCache

    nl, kh, dh = (2, 2, 64) if smoke else (4, 8, 128)
    page_size, n_pages, pages_move = 16, 32, 8
    rng = np.random.default_rng(0)
    kv = {"k": jnp.asarray(rng.standard_normal(
              (nl, n_pages, page_size, kh, dh)), jnp.float32),
          "v": jnp.asarray(rng.standard_normal(
              (nl, n_pages, page_size, kh, dh)), jnp.float32)}
    cache = PagedKVCache(kv, n_pages, page_size, n_slots=2)
    cache.attach_host_pool(64)
    rid = 0
    cache.tables[rid] = cache.allocator.alloc(pages_move)

    cache.swap_out(rid)          # compile both fused dispatches
    cache.swap_in(rid)
    t_out = t_in = 0.0
    for _ in range(iters):
        t0 = time.perf_counter()
        _, nbytes = cache.swap_out(rid)
        t_out += time.perf_counter() - t0
        t0 = time.perf_counter()
        cache.swap_in(rid)
        jax.tree.leaves(cache.state["kv"])[0].block_until_ready()
        t_in += time.perf_counter() - t0

    rows = []
    print("op,pages,bytes_per_cycle,us_per_cycle,gib_per_s")
    for label, wall in (("swap_out_io", t_out), ("swap_in_io", t_in)):
        us = wall / iters * 1e6
        rows.append({
            "op": label, "pages": pages_move, "bytes_per_cycle": nbytes,
            "us_per_cycle": round(us, 1),
            "gib_per_s": round(nbytes / (wall / iters) / 2 ** 30, 3),
        })
        print(f"{label},{pages_move},{nbytes},{us:.1f},"
              f"{rows[-1]['gib_per_s']}")
    return rows


# required measurement fields per op family — `_check_schema` refuses to
# append a history row that lost one (mirrors serve_bench's row check)
_ROW_FIELDS = {
    "block_hadamard": ("cpu_ref_us", "model_bytes", "model_flops",
                       "v5e_time_us", "bound"),
    "hadamard_quant": ("cpu_ref_us", "model_bytes", "model_flops",
                       "v5e_time_us", "bound"),
    "fusion_hbm": ("value",),
    "paged_attention": ("ctx", "kv_heads", "q_heads", "kv_splits",
                        "page_size", "batch", "pages_per_step",
                        "us_per_step"),
    "decode": ("decode_step_us",),
    "swap": ("pages", "bytes_per_cycle", "us_per_cycle", "gib_per_s"),
}


def _check_schema(rows):
    """Every row must carry `op` plus the measurement fields its op family
    defines — a bench path that crashed mid-collection or renamed a field
    fails the smoke job instead of silently appending a partial row to the
    JSON history."""
    for row in rows:
        op = row.get("op")
        if not op:
            raise ValueError(f"bench row {row!r} is missing 'op'")
        for prefix, fields in _ROW_FIELDS.items():
            if op.startswith(prefix):
                missing = [k for k in fields if k not in row]
                if missing:
                    raise ValueError(
                        f"bench row {op!r} is missing required field(s) "
                        f"{missing}; refusing to write partial history")
                break
        else:
            raise ValueError(f"bench row has unknown op family {op!r}; "
                             "add its required fields to _ROW_FIELDS")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI: compiles every bench path "
                    "once, minimal wall time")
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_kernels.json"))
    args = ap.parse_args(argv)

    rows = []
    if not args.smoke:
        rows += hadamard_rows()
    rows += paged_attention_bench(smoke=args.smoke)
    rows += decode_step_bench()
    rows += swap_io_bench(smoke=args.smoke)

    out = {
        "bench": "kernels",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "rows": rows,
    }
    _check_schema(rows)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    history = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            history = json.load(f).get("history", [])
    history.append(out)
    with open(args.out, "w") as f:
        json.dump({"history": history}, f, indent=1)
    print(f"wrote {args.out} ({len(history)} entries)")


if __name__ == "__main__":
    main()
