"""Kernel micro-benchmarks (interpret-mode correctness cost + jnp-reference
wall time on CPU; TPU wall-time comes from the roofline, not this host).

Reports per-op bytes/FLOPs and the modeled v5e time for the block-Hadamard
rotation and the fused rotate+quant kernel, plus the measured CPU time of
the jnp reference (sanity anchor, not a perf claim).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as kref

HBM_BW = 819e9
PEAK = 197e12


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def main(argv=None):
    m, d, b = 2048, 8192, 32
    x = jax.random.normal(jax.random.PRNGKey(0), (m, d), jnp.float32)

    rot = jax.jit(lambda x: kref.block_hadamard_ref(x, b))
    us_rot = _time(rot, x)
    fused = jax.jit(lambda x: kref.hadamard_quant_ref(x, b))
    us_fused = _time(fused, x)

    bytes_unfused = m * d * 2 * 2 + (m * d * 2 + m * d * 1 + m * 8)
    bytes_fused = m * d * 2 + m * d * 1 + m * 8
    flops_rot = 2 * m * d * b

    print("# kernel model (v5e bf16) + CPU jnp reference timing")
    print("op,cpu_ref_us,model_bytes,model_flops,v5e_time_us,bound")
    t_mem = m * d * 2 * 2 / HBM_BW * 1e6
    t_cmp = flops_rot / PEAK * 1e6
    print(f"block_hadamard_b{b},{us_rot:.0f},{m*d*4},{flops_rot},"
          f"{max(t_mem,t_cmp):.1f},{'memory' if t_mem>t_cmp else 'compute'}")
    t_mem_f = bytes_fused / HBM_BW * 1e6
    print(f"hadamard_quant_fused_b{b},{us_fused:.0f},{bytes_fused},"
          f"{flops_rot},{max(t_mem_f,t_cmp):.1f},memory")
    saving = 1 - bytes_fused / bytes_unfused
    print(f"fusion_hbm_byte_saving,{saving:.3f}")


if __name__ == "__main__":
    main()
