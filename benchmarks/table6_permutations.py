"""Table 6: permutation strategies under a fixed PeRQ pipeline (b=32,
Qronos): identity < random < absmax < zigzag ≤ massdiff."""
from repro.core import pipeline as PL

from .common import bench_model, eval_ppl, quantize_and_eval

METHODS = ["identity", "random", "absmax", "zigzag", "massdiff"]


def run(block_size: int = 16):
    cfg, model, params, corpus = bench_model()
    rows = [("bf16", eval_ppl(model, params, corpus))]
    for perm in METHODS:
        ptq = PL.PTQConfig(block_size=block_size, permutation=perm,
                           rotation="quarot", rounding="qronos")
        rows.append((perm, quantize_and_eval(model, params, corpus, ptq,
                                             n_eval=4)))
    return rows


def main(argv=None):
    rows = run()
    print("# Table6 surrogate (b=16, qronos)")
    print("permutation,ppl")
    for name, ppl in rows:
        print(f"{name},{ppl:.3f}")


if __name__ == "__main__":
    main()
