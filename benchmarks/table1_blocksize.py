"""Table 1 / Table 5: WikiText2-style perplexity across block sizes,
No-Permute vs PeRQ (MassDiff), under Qronos (Table 1) or RTN (Table 5).

Paper claims reproduced (as orderings at CPU scale):
  * PeRQ ≤ No-Permute at every block size, largest gains at small b;
  * both approach the full-vector rotation as b → d_ff;
  * PeRQ closes the gap at much smaller b.
"""
from __future__ import annotations

import argparse

from repro.core import pipeline as PL

from .common import bench_model, eval_ppl, quantize_and_eval


def run(rounding: str = "qronos", block_sizes=(8, 16, 32, 64, 128, 256)):
    cfg, model, params, corpus = bench_model()
    fp_ppl = eval_ppl(model, params, corpus)
    rows = [("bf16", "-", fp_ppl)]
    for b in block_sizes:
        full = b >= cfg.d_ff
        for perm, label in (("identity", "no_permute"),
                            ("massdiff", "perq")):
            ptq = PL.PTQConfig(block_size=b, permutation=perm,
                               rotation="quarot", rounding=rounding,
                               full_vector_r3=full)
            ppl = quantize_and_eval(model, params, corpus, ptq)
            rows.append((label, b, ppl))
    # full-vector reference (QuaRot)
    ptq = PL.preset("quarot", rounding=rounding) if rounding != "qronos" \
        else PL.preset("quarot")
    rows.append(("full_vector", "-",
                 quantize_and_eval(model, params, corpus, ptq)))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounding", default="qronos",
                    choices=["qronos", "gptq", "rtn"])
    args = ap.parse_args(argv)
    rows = run(args.rounding)
    print(f"# Table1 surrogate (rounding={args.rounding})")
    print("method,block_size,ppl")
    for label, b, ppl in rows:
        print(f"{label},{b},{ppl:.3f}")


if __name__ == "__main__":
    main()
