"""Figures 4 & 5: (a) normalized Prop-3.2 bound vs block size with the
1/√b sufficient threshold and 1/b floor; (b) the bound tracks per-token
quantization error, and MassDiff tightens it for ~100% of tokens with a
30–45% mean error reduction (paper: 37.5–40.5%), beating ZigZag.
"""
import math

import numpy as np
import jax.numpy as jnp

from repro.core import bounds, massdiff as MD
from repro.core.hadamard import block_hadamard_transform
from repro.core.quantizers import QuantSpec, quantize_act

from .fig3_delta import collect_down_activations


def quant_err(x, b):
    xr = block_hadamard_transform(x, b)
    xq = quantize_act(xr, QuantSpec(fmt="int4"))
    return np.asarray(jnp.linalg.norm(xq - xr, axis=-1))


def run(b: int = 16):
    x = jnp.asarray(collect_down_activations()[:512])
    d = x.shape[-1]
    out = {"d": d}

    # Fig 4: bound vs block size
    curve = []
    bs = [bb for bb in (4, 8, 16, 32, 64, 128, 256) if d % bb == 0 and bb <= d]
    for bb in bs:
        z = np.asarray(bounds.prop32_bound(x, bb)) / math.sqrt(bb)
        linf = np.asarray(jnp.max(jnp.abs(x), -1))
        curve.append((bb, float((z / linf).mean()), 1 / math.sqrt(bb), 1 / bb))
    out["fig4"] = curve

    # Fig 5: bound vs error, per permutation strategy
    xn = np.asarray(x)
    linf = np.abs(xn).max(-1)
    base_bound = np.asarray(bounds.prop32_bound(x, b)) / math.sqrt(b) / linf
    base_err = quant_err(x, b) / linf

    def permuted(perm_method):
        # per-token permutation, like the paper's Fig 5 protocol
        errs, bnds, tightened = [], [], 0
        for i in range(xn.shape[0]):
            xi = xn[i:i + 1]
            perm = MD.make_permutation(perm_method, xi, b)
            xp = jnp.asarray(xi[:, perm])
            bnd = float(bounds.prop32_bound(xp, b)[0]) / math.sqrt(b) / linf[i]
            err = float(quant_err(xp, b)[0]) / linf[i]
            tightened += bnd <= base_bound[i] * (1 + 1e-9)
            errs.append(err)
            bnds.append(bnd)
        return (np.asarray(errs), np.asarray(bnds),
                tightened / xn.shape[0])

    md_err, md_bnd, md_tight = permuted("massdiff")
    zz_err, zz_bnd, zz_tight = permuted("zigzag")
    corr = float(np.corrcoef(base_bound, base_err)[0, 1])
    out["fig5"] = {
        "corr_bound_error": corr,
        "massdiff_frac_bound_tightened": md_tight,
        "zigzag_frac_bound_tightened": zz_tight,
        "massdiff_mean_err_reduction":
            float(1 - (md_err / np.maximum(base_err, 1e-9)).mean()),
        "zigzag_mean_err_reduction":
            float(1 - (zz_err / np.maximum(base_err, 1e-9)).mean()),
    }
    return out


def main(argv=None):
    r = run()
    print("# Fig4 surrogate: b,mean_norm_bound,suff_1/sqrt(b),floor_1/b")
    for row in r["fig4"]:
        print(",".join(f"{v:.5f}" if isinstance(v, float) else str(v)
                       for v in row))
    print("# Fig5 surrogate")
    for k, v in r["fig5"].items():
        print(f"{k},{v:.4f}")
    f5 = r["fig5"]
    assert f5["massdiff_frac_bound_tightened"] >= 0.99
    assert f5["massdiff_mean_err_reduction"] >= \
        f5["zigzag_mean_err_reduction"] - 1e-6


if __name__ == "__main__":
    main()
