"""Figure 3: mass concentration δ vs outlier suppression ratio, on real
(trained-model) activations vs per-token-fitted Gaussian/Laplace samples.

Claims checked: (1) suppression occurs for almost all tokens even when the
sufficient condition δ < 1/√d fails; (2) δ correlates strongly with the
suppression ratio; (3) fitted-distribution δ's differ from the real ones.
"""
import numpy as np
import jax, jax.numpy as jnp

from repro.core import bounds
from repro.core.hadamard import hadamard_transform
from repro.core.pipeline import _Capture
from repro.models.transformer import build_model

from .common import bench_model, calib_batches


def collect_down_activations():
    cfg, model, params, corpus = bench_model()
    cap = _Capture()
    cmodel = build_model(cfg, quant_hooks=cap.hooks())
    for b in calib_batches(corpus, cfg, n=1):
        cap.reset_forward()
        cmodel.forward(params, b, unroll=True)
    # third (or last) layer's down-projection input, like the paper
    layer = min(2, cfg.n_layers - 1)
    return cap.get("down", layer)


def run():
    x = jnp.asarray(collect_down_activations()[:1024])
    d = x.shape[-1]
    delta = np.asarray(bounds.mass_concentration(x))
    xr = hadamard_transform(x)
    ratio = np.asarray(bounds.suppression_ratio(x, xr))
    corr = float(np.corrcoef(delta, ratio)[0, 1])
    suppressed = float((ratio < 1.0).mean())

    # per-token fitted Gaussian / Laplace surrogates
    rng = np.random.default_rng(0)
    xn = np.asarray(x)
    mu, sd = xn.mean(-1, keepdims=True), xn.std(-1, keepdims=True)
    bscale = np.abs(xn - np.median(xn, -1, keepdims=True)).mean(-1,
                                                                keepdims=True)
    gauss = rng.normal(mu, sd, xn.shape).astype(np.float32)
    lap = rng.laplace(np.median(xn, -1, keepdims=True), bscale,
                      xn.shape).astype(np.float32)
    d_gauss = np.asarray(bounds.mass_concentration(jnp.asarray(gauss)))
    d_lap = np.asarray(bounds.mass_concentration(jnp.asarray(lap)))
    return {
        "d": d, "suff_threshold": d ** -0.5,
        "delta_mean": float(delta.mean()), "delta_p05": float(np.quantile(delta, .05)),
        "ratio_mean": float(ratio.mean()), "frac_suppressed": suppressed,
        "corr_delta_ratio": corr,
        "delta_gauss_mean": float(d_gauss.mean()),
        "delta_laplace_mean": float(d_lap.mean()),
    }


def main(argv=None):
    r = run()
    print("# Fig3 surrogate")
    for k, v in r.items():
        print(f"{k},{v}")
    assert r["frac_suppressed"] > 0.95
    assert r["corr_delta_ratio"] > 0.5


if __name__ == "__main__":
    main()
