"""Serve a PeRQ-quantized model through the paged-KV serving engine.

Demonstrates the serving half of the framework: quantize with PeRQ*, then
run batched requests through `repro.serve.engine` with the online
block-Hadamard + W4A4 path live in every forward call.

The serving engine
------------------
`ServeEngine` replaces the legacy dense-slot scheduler with three pieces:

* **Paged KV cache** (`engine.pages`): KV lives in fixed-size pages in one
  shared pool; each sequence holds a block table of page ids, allocated as
  it grows and freed on completion. Pages store whatever the backend's
  cache format needs — bf16 K/V, or int8/int4 codes *plus* the asymmetric
  per-(position, head) scale/zero rows of the integer KV cache.
* **Continuous batching + chunked prefill** (`engine.scheduler`): prompts
  stream through `forward_chunk` several tokens per step instead of the
  old one-token-per-step drip; decodes advance every generating sequence
  in one batched call with per-slot fill positions; admission happens
  whenever pages free up, under a per-step token budget that interleaves
  prefill with decode. Per-request `SamplingParams` carry temperature and
  length, with a fresh PRNG key split per step.
* **Unified adapter** (`engine.adapter`): the same engine serves the bf16
  model, the fake-quant PTQ output (shown here), and the packed-int4
  `QuantizedDenseLM` — `as_servable(model, params)` picks the adapter.
* **Telemetry** (`repro.serve.telemetry`): every engine counter lives in
  a `MetricsRegistry` exported via `engine.metrics_snapshot()` (versioned,
  schema-validated), and an optional `Tracer` records request lifecycles
  and fused dispatches as Chrome Trace JSON for Perfetto — both shown
  below. Tracing is bit-path-neutral: generations are identical with it
  on or off.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core import pipeline as PL
from repro.core.synthetic import inject_outlier_channels
from repro.models.transformer import build_model
from repro.serve.engine import (EngineRequest, SamplingParams, ServeEngine,
                                as_servable)
from repro.serve.telemetry import Tracer, validate_snapshot

cfg = get_config("qwen1.5-0.5b").reduced()
model = build_model(cfg)
params = inject_outlier_channels(model.init(jax.random.PRNGKey(0)))

key = jax.random.PRNGKey(1)
calib = [{"tokens": jax.random.randint(key, (4, 128), 0, cfg.vocab),
          "labels": jnp.zeros((4, 128), jnp.int32)}]
result = PL.quantize_model(model, params, calib,
                           PL.preset("perq_star", block_size=16))
qmodel = PL.build_quantized_model(model, result)

tracer = Tracer()
engine = ServeEngine(as_servable(qmodel, result.params, name="fake-quant"),
                     n_pages=33, page_size=8, max_seqs=4, prefill_chunk=8,
                     tracer=tracer)
rng = np.random.default_rng(0)
for rid in range(6):
    prompt = rng.integers(0, cfg.vocab, size=rng.integers(3, 9)).tolist()
    engine.submit(EngineRequest(rid=rid, prompt=prompt,
                                sampling=SamplingParams(max_new=8)))

done = engine.run()
print(f"served {len(done)} requests in {engine.n_steps} engine steps "
      f"(paged KV over {engine.kv.allocator.capacity} pages, "
      f"{engine.n_prefill_tokens} prefill + {engine.n_decode_tokens} "
      f"decode tokens)")
for r in sorted(done, key=lambda r: r.rid):
    print(f"  req {r.rid}: prompt {r.prompt} → generated {r.generated}")

# the registry snapshot is the one export surface: versioned, validated,
# and the source for the launcher's summary line and the serve bench rows
snap = engine.metrics_snapshot()
validate_snapshot(snap)
occ = snap["histograms"]["engine.decode.batch_occupancy"]
print(f"telemetry: schema v{snap['schema_version']}, "
      f"peak pages {snap['gauges']['engine.pages.peak_in_use']:.0f}, "
      f"decode batch occupancy p50 {occ['p50']:.2f}")
tracer.save("/tmp/serve_trace.json")    # open in https://ui.perfetto.dev
print(f"trace: {len(tracer.events)} events → /tmp/serve_trace.json")
