"""Serve a PeRQ-quantized model with continuous batching.

Demonstrates the serving half of the framework: quantize with PeRQ*, then
run batched requests through the slot-based scheduler (per-slot KV cache
indices; prompt prefill and generation interleave across slots), with the
online block-Hadamard + W4A4 path live in every decode step.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core import pipeline as PL
from repro.core.synthetic import inject_outlier_channels
from repro.models.transformer import build_model
from repro.serve.step import BatchScheduler, Request

cfg = get_config("qwen1.5-0.5b").reduced()
model = build_model(cfg)
params = inject_outlier_channels(model.init(jax.random.PRNGKey(0)))

key = jax.random.PRNGKey(1)
calib = [{"tokens": jax.random.randint(key, (4, 128), 0, cfg.vocab),
          "labels": jnp.zeros((4, 128), jnp.int32)}]
result = PL.quantize_model(model, params, calib,
                           PL.preset("perq_star", block_size=16))
qmodel = PL.build_quantized_model(model, result)

rng = np.random.default_rng(0)
sched = BatchScheduler(qmodel, result.params, slots=4, max_len=64)
for rid in range(6):
    prompt = rng.integers(0, cfg.vocab, size=rng.integers(3, 9)).tolist()
    sched.submit(Request(rid=rid, prompt=prompt, max_new=8))

steps = 0
done = []
while sched.queue or sched.active:
    done.extend(sched.step())
    steps += 1

print(f"served {len(done)} requests in {steps} decode steps "
      f"(continuous batching over 4 slots)")
for r in sorted(done, key=lambda r: r.rid):
    print(f"  req {r.rid}: prompt {r.prompt} → generated {r.generated}")
