"""End-to-end driver: TRAIN a ~small LM for a few hundred steps on the
synthetic corpus (with checkpointing + the fault-tolerant driver), then
post-training-quantize it with PeRQ and compare perplexities across
pipelines — the paper's Table-1/2 protocol compressed into one script.

    PYTHONPATH=src python examples/quantize_llm.py [--steps 300]
"""
import argparse
import math
import os
import tempfile

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.core import pipeline as PL
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticCorpus, \
    batch_iterator
from repro.models.transformer import build_model
from repro.optim import adamw
from repro.runtime.driver import RuntimeConfig, TrainDriver
from repro.train.step import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--eval-batches", type=int, default=8)
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args()

    cfg = get_config("llama3-1b").reduced(
        n_layers=4, d_model=128, vocab=512, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = SyntheticCorpus(cfg.vocab, seed=0)

    # ---- train (fault-tolerant driver + checkpoints) ----
    workdir = args.workdir or tempfile.mkdtemp(prefix="perq_example_")
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=20,
                                total_steps=args.steps)
    opt = adamw.init_state(opt_cfg, params)
    step = jax.jit(make_train_step(model, opt_cfg, TrainConfig(remat=False)))
    mgr = CheckpointManager(os.path.join(workdir, "ckpt"), keep=2)
    mgr.save(0, {"params": params, "opt": opt}, blocking=True)
    driver = TrainDriver(step, mgr, RuntimeConfig(checkpoint_every=100))
    it = Prefetcher(batch_iterator(corpus, DataConfig(cfg.vocab, 64, 16)))
    print(f"training {args.steps} steps → {workdir}")
    (params, opt), _ = driver.run(params, opt, it, num_steps=args.steps)
    it.close()

    # ---- evaluate fp baseline ----
    def ppl(p, hooks=None):
        m = build_model(cfg, quant_hooks=hooks) if hooks else model
        ev = jax.jit(lambda pp, b: m.loss_fn(pp, b)[1]["nll"])
        eit = batch_iterator(corpus, DataConfig(cfg.vocab, 64, 16, seed=999))
        tot = sum(float(ev(p, next(eit))) for _ in range(args.eval_batches))
        return math.exp(tot / args.eval_batches)

    fp_ppl = ppl(params)
    print(f"\nbf16 perplexity: {fp_ppl:.3f}")

    # ---- PTQ across pipelines ----
    cit = batch_iterator(corpus, DataConfig(cfg.vocab, 128, 8, seed=77))
    calib = [next(cit) for _ in range(2)]
    print(f"{'pipeline':14s} {'ppl':>9s} {'vs bf16':>9s}")
    for name in ["rtn_only", "mr_rtn", "mr_qronos", "perq_star",
                 "perq_dagger", "quarot"]:
        res = PL.quantize_model(model, params, calib,
                                PL.preset(name, cayley_steps=8))
        q = ppl(res.params, hooks=res.hooks)
        print(f"{name:14s} {q:9.3f} {q / fp_ppl:9.2f}x")


if __name__ == "__main__":
    main()
