"""Quickstart: the PeRQ pipeline on a small LM in ~30 lines of API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core import pipeline as PL
from repro.core.synthetic import inject_outlier_channels
from repro.models.transformer import build_model

# 1. a model (any of the 11 registry archs; reduced() for CPU scale)
cfg = get_config("llama3-1b").reduced()
model = build_model(cfg)
params = inject_outlier_channels(model.init(jax.random.PRNGKey(0)))

# 2. calibration data (here random tokens; real runs use the data pipeline)
key = jax.random.PRNGKey(1)
calib = [{"tokens": jax.random.randint(key, (4, 128), 0, cfg.vocab),
          "labels": jnp.zeros((4, 128), jnp.int32)}]

# 3. quantize: PeRQ* = MassDiff + QuaRot rotations + block-Hadamard R̃₃ + Qronos
result = PL.quantize_model(model, params, calib, PL.preset("perq_star"))

# 4. run the quantized model (W4A4, online block rotation at the down proj)
qmodel = PL.build_quantized_model(model, result)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 64),
                                      0, cfg.vocab)}
logits_fp = model.forward(params, batch)
logits_q = qmodel.forward(result.params, batch)

err = jnp.mean((logits_q - logits_fp) ** 2) / jnp.mean(logits_fp ** 2)
print(f"relative output MSE after INT4 W4A4 PeRQ*: {float(err):.4f}")
print("per-layer max-block ℓ1 mass before → after MassDiff:")
for i, e in enumerate(result.report["per_layer"][:4]):
    print(f"  layer {i}: {e['max_block_l1_before']:.2f} → "
          f"{e['max_block_l1_after']:.2f}")
